"""Sharded-pytree checkpointing on scda — the framework's core feature.

``save`` writes one scda file whose bytes depend only on the *logical*
train state (leaf values in canonical row-major order), never on the mesh,
process count, or sharding — the paper's serial-equivalence, delivered for
JAX pytrees.  ``restore`` rebuilds the state under *any* target sharding /
mesh ("the file can be read on any number of processes that agree on any
partition"), which is what makes restarts elastic.

Both hot paths are overlapped pipelines (:mod:`repro.core.pipeline`).
Restore: the scheduler walks the :class:`ScdaIndex` once, sorts every
wanted leaf's runs by file offset, prefetches the next
``REPRO_SCDA_PREFETCH`` bytes of extents on a background executor, and
inflates compressed chunks on the codec thread pool while the next leaf's
preads are in flight.  Save: the scheduler plans every leaf's extents
from the manifest, snapshots device arrays one leaf ahead, deflates
chunks on the same pool, and drains coalesced ``pwritev`` fragments
through a background queue bounded to ``REPRO_SCDA_WRITE_PIPELINE``
in-flight bytes.  Results are byte-identical to the serial walks;
``REPRO_SCDA_PREFETCH=0`` / ``REPRO_SCDA_WRITE_PIPELINE=0`` (or the
``prefetch_bytes`` / ``write_window`` arguments) disable each engine and
take the exact legacy serial order — the oracles the pipelines are
tested against.

File layout:
    F  header (vendor "repro scda-jax 0.1")
    I  "scda-ckpt status"    — human-readable step number
    B  "scda-ckpt manifest"  — JSON: leaf names/shapes/dtypes/layout + aux
    per array leaf, in manifest order:
        raw:        A("leaf NNNNNN", N = nbytes, E = 1)
        compressed: §3.4 convention (A of U-entries + V of deflate chunks),
                    fixed chunking recorded in the manifest
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import layout, manifest as mf
from repro.core import ScdaError, ScdaErrorCode, partition
from repro.core import trace as _trace
from repro.core.comm import Communicator, SerialComm
from repro.core.index import ScdaIndex
from repro.core.io_backend import prefetch_window, write_pipeline_window
from repro.core.pipeline import ReadItem, run_pipeline
from repro.core.reader import ScdaReader, fopen_read
from repro.core.writer import fopen_write

DEFAULT_CHUNK_BYTES = 1 << 20  # 1 MiB deflate chunks for encoded leaves


def _effective_prefetch(prefetch_bytes: Optional[int]) -> int:
    """Resolve the prefetch window: explicit argument wins, else the
    ``REPRO_SCDA_PREFETCH`` environment knob (0 = serial restore)."""
    if prefetch_bytes is None:
        return prefetch_window()
    return max(0, int(prefetch_bytes))


def _effective_write_window(write_window: Optional[int]) -> int:
    """Resolve the save-pipeline window: explicit argument wins, else the
    ``REPRO_SCDA_WRITE_PIPELINE`` environment knob (0 = serial save)."""
    if write_window is None:
        return write_pipeline_window()
    return max(0, int(write_window))


#: ``REPRO_SCDA_VERIFY_RESTORE=1``: CRC-check every restored archive
#: against its checksummed sidecar (as if ``restore(..., verify=True)``).
VERIFY_RESTORE_ENV = "REPRO_SCDA_VERIFY_RESTORE"


def _effective_verify(verify: Optional[bool]) -> bool:
    """Resolve verify-on-restore: explicit argument wins, else the
    ``REPRO_SCDA_VERIFY_RESTORE`` environment knob."""
    if verify is not None:
        return bool(verify)
    return os.environ.get(VERIFY_RESTORE_ENV, "0") not in ("", "0")


def _verify_archive(path: str) -> None:
    """Verify every section payload of ``path`` against its checksummed
    ``.scdax`` sidecar — the ``restore(..., verify=True)`` pass.

    Requires a fresh, fully checksummed sidecar (``scdatool index
    --checksums``); a missing/stale one raises ARG_SEQUENCE rather than
    silently skipping, and a CRC mismatch raises CORRUPT_CHECKSUM with
    the failing section's exact payload byte offset
    (``ScdaError.offset``).  Runs on its own reader so the caller's
    cursor and adopted index are untouched.
    """
    try:
        idx = ScdaIndex.load_sidecar(path)
    except (ScdaError, OSError) as e:
        raise ScdaError(
            ScdaErrorCode.ARG_SEQUENCE,
            f"{path}: restore(verify=True) needs a fresh checksummed "
            f"sidecar — run scdatool index --checksums ({e})") from e
    with _trace.span("verify", "ckpt", path=path):
        with fopen_read(None, path) as vr:
            idx.check_checksums(vr)


# --------------------------------------------------------------------------
# Tree flattening with stable, human-readable names
# --------------------------------------------------------------------------

def _key_name(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def leaf_name(path) -> str:
    return "/".join(_key_name(k) for k in path) or "."


def flatten_named(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(leaf_name(p), v) for p, v in flat]
    names = [n for n, _ in named]
    if len(set(names)) != len(names):
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        "pytree leaf names are not unique")
    return named, treedef


def _is_array(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray)) and np.ndim(x) is not None


# --------------------------------------------------------------------------
# Saving
# --------------------------------------------------------------------------

def _byte_view(host: np.ndarray) -> memoryview:
    """A zero-copy byte view of a contiguous array (bf16/f8-safe — the
    ml_dtypes scalar types have no buffer protocol, uint8 views do)."""
    if host.nbytes == 0:
        return memoryview(b"")
    return memoryview(np.ascontiguousarray(host).reshape(-1).view(np.uint8))


def _owned_windows(arr, nbytes: int) -> List[Tuple[int, memoryview]]:
    """This process's deduplicated (byte_offset, buffer) windows of ``arr``.

    For a jax.Array, every addressable shard with replica_id == 0 is owned
    here; across all processes that tiles the canonical stream exactly once.
    numpy arrays are treated as fully owned (callers pass them on rank 0 or
    rely on identical replicated writes, which are byte-identical anyway).

    A 2-D-sharded tensor's shards interleave in the canonical stream;
    ``ScdaWriter.write_array_windows`` sorts the windows and coalesces runs
    that are contiguous *across shards* into single vectored writes.
    """
    windows: List[Tuple[int, memoryview]] = []
    if isinstance(arr, jax.Array):
        for shard in arr.addressable_shards:
            if shard.replica_id != 0:
                continue
            host = np.asarray(shard.data)
            buf = _byte_view(host)
            for goff, loff, length in layout.shard_runs(
                    arr.shape, shard.index, arr.dtype.itemsize):
                windows.append((goff, buf[loff:loff + length]))
    else:
        host = np.asarray(arr)
        if host.nbytes:
            windows.append((0, _byte_view(host)))
    return windows


def save(path: str, tree, *, comm: Optional[Communicator] = None,
         step: Optional[int] = None, compressed: bool = False,
         chunk_bytes: int = DEFAULT_CHUNK_BYTES,
         aux_extra: Optional[Dict[str, Any]] = None,
         write_window: Optional[int] = None,
         record_hashes: bool = False,
         delta_base: Optional[Tuple[Dict[str, Any], str]] = None,
         shards: Optional[int] = None,
         parity: Optional[int] = None,
         trace: Optional[Any] = None) \
        -> Dict[str, Any]:
    """Write ``tree`` to ``path`` as a serial-equivalent scda checkpoint.

    Leaf sections go through the overlapped save engine
    (:func:`repro.core.pipeline.run_write_pipeline`): device→host
    snapshots run one leaf ahead, compressed chunks deflate on the codec
    pool, and finished fragments drain through a background ``pwritev``
    queue bounded to ``write_window`` in-flight bytes (default
    ``REPRO_SCDA_WRITE_PIPELINE``, 32 MiB).  ``write_window=0`` saves
    serially, in exactly the pre-pipeline write order — the byte oracle
    the pipeline is fuzzed against.  Either way the file bytes depend
    only on the logical tree: serial equivalence is preserved by
    construction, since both paths plan sections with the same writer
    primitives (:mod:`repro.checkpoint.planner`).

    ``record_hashes`` adds per-chunk content digests (CRC32 + a 128-bit
    SHA-256 prefix)
    to the manifest so the archive can serve as a delta base.
    ``delta_base`` — a ``(base_manifest_doc, base_file_name)`` pair —
    switches to an incremental save: chunks whose digests match the base
    are stored as by-hash references and only changed chunks are
    written (:mod:`repro.checkpoint.delta`).  Both are single-rank.

    Returns the manifest document (what :func:`read_manifest` of the
    fresh file would return).

    ``shards`` splits the save into that many independent scda archives
    plus a manifest file at ``path`` (see
    :mod:`repro.checkpoint.sharding`); ``None`` defers to the
    ``REPRO_SCDA_SHARDS`` knob, 0 writes the classic single file.  A
    sharded save returns the sharded manifest document instead.

    ``parity`` adds that many erasure-code shards to a sharded save
    (``None`` defers to ``REPRO_SCDA_PARITY``; ignored for flat saves —
    there is no shard set to code over).  See
    :mod:`repro.checkpoint.redundancy`.

    ``trace`` activates telemetry for this one save: a
    :class:`repro.core.trace.TraceCollector` (events/metrics accumulate
    there) or a path string (a Chrome ``trace_event`` JSON is exported
    on completion).  ``None`` leaves the process-wide
    ``REPRO_SCDA_TRACE`` behavior in charge.  Purely observational —
    traced saves are byte-identical to untraced ones.
    """
    if trace is not None:
        with _trace.scoped(trace):
            return save(path, tree, comm=comm, step=step,
                        compressed=compressed, chunk_bytes=chunk_bytes,
                        aux_extra=aux_extra, write_window=write_window,
                        record_hashes=record_hashes,
                        delta_base=delta_base, shards=shards,
                        parity=parity)
    comm = comm or SerialComm()
    from repro.checkpoint import redundancy as _red
    from repro.checkpoint import sharding as _sharding
    n_shards = _sharding.shards_default() if shards is None else \
        max(0, int(shards))
    n_parity = _red.parity_default() if parity is None else \
        max(0, int(parity))
    with _trace.span("save", "ckpt", path=path, step=step,
                     shards=n_shards, parity=n_parity,
                     compressed=compressed):
        if n_shards:
            _red.check_geometry(n_shards, n_parity)
            return _sharding.save_sharded(
                path, tree, shards=n_shards, comm=comm, step=step,
                compressed=compressed, chunk_bytes=chunk_bytes,
                aux_extra=aux_extra, write_window=write_window,
                record_hashes=record_hashes, delta_base=delta_base,
                parity=n_parity)
        named, _ = flatten_named(tree)
        leaves: List[mf.LeafSpec] = []
        arrays: List[Any] = []
        aux: Dict[str, Any] = dict(aux_extra or {})
        for name, value in named:
            if _is_array(value):
                leaves.append(mf.LeafSpec.make(
                    name, tuple(np.shape(value)), value.dtype,
                    compressed, chunk_bytes))
                arrays.append(value)
            else:
                aux[name] = _encode_aux(value)
        return _write_checkpoint(
            path, comm=comm, step=step, leaves=leaves, arrays=arrays,
            aux=aux, compressed=compressed, chunk_bytes=chunk_bytes,
            write_window=write_window, record_hashes=record_hashes,
            delta_base=delta_base)


def _write_checkpoint(path: str, *, comm: Optional[Communicator],
                      step: Optional[int], leaves: List[mf.LeafSpec],
                      arrays: List[Any], aux: Dict[str, Any],
                      compressed: bool, chunk_bytes: int,
                      write_window: Optional[int],
                      record_hashes: bool = False,
                      delta_base: Optional[Tuple[Dict[str, Any], str]]
                      = None) -> Dict[str, Any]:
    """The save core shared by :func:`save` and ``scdatool squash``:
    already-flattened leaves → digests → placement plan → archive.

    Splitting "what bytes does this leaf produce" from "where do they
    land" lives here: every layout builds :class:`planner.LeafPlacement`
    objects and one emission loop (:func:`planner.write_placements`)
    drives them through the serial oracle or the overlapped engine.
    Given identical inputs the output bytes are identical regardless of
    the caller — which is what makes a squashed chain byte-equal to a
    direct full save.
    """
    from repro.checkpoint import planner
    comm = comm or SerialComm()
    ww = _effective_write_window(write_window)
    if compressed and comm.size > 1:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        "compressed checkpoints require chunk-aligned "
                        "partitions; use comm.size == 1 (async snapshot)")
    if (record_hashes or delta_base is not None) and comm.size > 1:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        "content-hashed / delta checkpoints are "
                        "single-rank; use comm.size == 1 (async snapshot)")

    if record_hashes or delta_base is not None:
        # Digesting touches every byte, so snapshot to host eagerly (the
        # manager pre-snapshots anyway) and reuse the host arrays for
        # the section payloads — one device→host copy, not two.  The
        # delta leg computes the strong hash only; CRC32s are filled in
        # by the planner (computed for stored chunks, inherited from the
        # base for unchanged ones), so save cost tracks changed bytes.
        hosts: List[Any] = []
        for spec_, arr in zip(leaves, arrays):
            host = np.asarray(arr)
            sizes = layout.chunk_sizes(spec_["nbytes"], chunk_bytes)
            view = _byte_view(host)
            if delta_base is not None:
                spec_["chunks"] = {
                    "bytes": int(chunk_bytes),
                    "hash": mf.chunk_strong_hashes(view, sizes)}
            else:
                crcs, hashes = mf.chunk_digests(view, sizes)
                spec_["chunks"] = {"bytes": int(chunk_bytes),
                                   "crc32": crcs, "hash": hashes}
            hosts.append(host)
        arrays = hosts
    delta_table: Optional[Dict[str, Any]] = None
    if delta_base is not None:
        from repro.checkpoint import delta as _delta
        base_doc, base_file = delta_base
        delta_table = _delta.plan_refs(
            leaves, base_doc, base_file,
            views=[_byte_view(h) for h in arrays])

    placements: List[planner.LeafPlacement] = []
    for i, (spec_, arr) in enumerate(zip(leaves, arrays)):
        user = mf.leaf_user_string(i)
        sizes = layout.chunk_sizes(spec_["nbytes"], chunk_bytes)
        if delta_table is not None:
            present = spec_["present"]
            if not present:
                continue  # unchanged leaf: references only, no section

            def snapshot(arr=arr, present=present, sizes=sizes):
                flat = _byte_view(np.asarray(arr))
                return [flat[c * chunk_bytes:c * chunk_bytes + sizes[c]]
                        for c in present]

            placements.append(planner.ChunkPlacement(
                user, [sizes[c] for c in present], snapshot, compressed,
                key=i))
        elif compressed:
            def snapshot(arr=arr, sizes=sizes):
                flat = _byte_view(np.asarray(arr))
                chunks, pos = [], 0
                for s in sizes:
                    chunks.append(flat[pos:pos + s])
                    pos += s
                return chunks

            placements.append(planner.ChunkPlacement(
                user, sizes, snapshot, True, key=i))
        else:
            def snapshot(arr=arr, spec_=spec_):
                return _owned_windows(arr, spec_["nbytes"])

            placements.append(planner.WindowPlacement(
                user, spec_["nbytes"], snapshot, key=i))

    # sync=True: checkpoints must be durable before the manager's atomic
    # rename commits them (every rank fsyncs at close).
    with _trace.span("write_archive", "ckpt", path=path,
                     sections=len(placements)):
        with fopen_write(comm, path, user_string=b"repro checkpoint",
                         sync=True) as f:
            f.write_inline(mf.STATUS_USER_STRING, mf.status_inline(step),
                           root=0)
            f.write_block(
                mf.MANIFEST_USER_STRING,
                mf.build(step, leaves, aux, delta_table)
                if comm.rank == 0 else None,
                E=None, root=0)
            planner.write_placements(f, placements, ww)
    return mf.document(step, leaves, aux, delta_table)


def _encode_aux(value) -> Any:
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (np.integer,)):
        return int(value)
    if isinstance(value, (np.floating,)):
        return float(value)
    raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                    f"unsupported non-array leaf type {type(value)!r}")


# --------------------------------------------------------------------------
# Restoring
# --------------------------------------------------------------------------

def _read_header_sections(r: ScdaReader) -> Dict[str, Any]:
    """Consume the leading status + manifest sections; returns the doc.

    Accepts both flat checkpoints and sharded-set manifests (told apart
    by the block's user string) — callers check ``doc["format"]`` and
    delegate sharded docs to :mod:`repro.checkpoint.sharding`.
    """
    hdr = r.read_section_header()
    if hdr.type != "I" or hdr.user_string != mf.STATUS_USER_STRING:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "not a repro checkpoint: missing status inline")
    step = mf.parse_status_inline(r.read_inline_data())
    hdr = r.read_section_header()
    if hdr.type != "B":
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "not a repro checkpoint: missing manifest block")
    if hdr.user_string == mf.MANIFEST_USER_STRING:
        doc = mf.parse(r.read_block_data())
    elif hdr.user_string == mf.SHARDS_MANIFEST_USER_STRING:
        doc = mf.parse_sharded(r.read_block_data())
    else:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "not a repro checkpoint: missing manifest block")
    if doc.get("step") is None:
        doc["step"] = step
    return doc


def _resolve_index(r: ScdaReader) -> "ScdaIndex":
    """The reader's index, salvaging a valid prefix on a torn tail.

    A checkpoint that was *committed* and then grew a torn post-commit
    append (a power cut mid journal-flush) is still a perfectly good
    checkpoint: every leaf the manifest names lives in the valid prefix.
    A full index build would raise CORRUPT_* on the torn tail and demote
    the whole file; instead, adopt the longest-valid-prefix index.  Safe
    by construction — every seek re-verifies the on-disk section header,
    and a leaf genuinely missing from the prefix still fails the restore
    (which then falls back to an older checkpoint, as before).
    """
    try:
        return r.index()
    except ScdaError as e:
        if e.group != 1:
            raise
        idx = ScdaIndex.build_prefix(r)
        # Keep the corruption error: if a *required* leaf turns out to be
        # missing from the prefix, the file was truncated mid-checkpoint
        # (not torn post-commit) and that original error is the truth.
        idx._salvage_error = e
        r.set_index(idx)
        return idx


def _adopt_sidecar(r: ScdaReader) -> None:
    """Give the reader a ``.scdax`` index if a fresh sidecar exists.

    Purely an optimization: without one, the reader's first seek builds
    the index with a single header-only scan; a stale or unreadable
    sidecar is ignored (and every seek re-checks the on-disk header, so
    even adopting a wrong-but-same-size sidecar cannot corrupt a restore).
    """
    try:
        r.set_index(ScdaIndex.load_sidecar(r.path))
    except (ScdaError, OSError):
        pass


def read_manifest(path: str, comm: Optional[Communicator] = None) \
        -> Dict[str, Any]:
    """Read just the status + manifest (cheap metadata probe)."""
    with fopen_read(comm, path) as r:
        return _read_header_sections(r)


def restore(path: str, like=None, *, comm: Optional[Communicator] = None,
            prefetch_bytes: Optional[int] = None,
            verify: Optional[bool] = None):
    """Restore a checkpoint.

    ``like``: an abstract pytree of ``jax.ShapeDtypeStruct`` (with optional
    ``.sharding``) or concrete arrays defining the target structure and
    placement.  With ``like=None`` a nested dict of numpy arrays is
    rebuilt from the manifest names.

    With ``like`` given the restore is *lazy*: each wanted leaf's section
    is reached by an index seek (``.scdax`` sidecar when fresh, one
    header-only scan otherwise) and unwanted leaves are never touched —
    restoring one tensor of a terabyte archive reads that tensor, the
    manifest, and nothing else.

    Reads run through the overlapped restore engine: all wanted leaf runs
    are sorted by file offset, prefetched ``prefetch_bytes`` ahead
    (default ``REPRO_SCDA_PREFETCH``, 4 MiB) on a background executor,
    and compressed chunks inflate on the codec pool while later preads
    are in flight.  ``prefetch_bytes=0`` restores serially (the byte
    oracle).  Returns ``(tree, step)``.

    ``verify=True`` (or ``REPRO_SCDA_VERIFY_RESTORE=1``) CRC-checks
    every section payload of each opened archive against its
    checksummed ``.scdax`` sidecar before any tensor is returned —
    mismatches raise CORRUPT_CHECKSUM with the exact failing byte
    offset.  Delta-chain *bases* are not re-verified per restore (cover
    them with ``scdatool verify --chain``).
    """
    comm = comm or SerialComm()
    pf = _effective_prefetch(prefetch_bytes)
    vfy = _effective_verify(verify)
    with _trace.span("restore", "ckpt", path=path):
        if vfy:
            _verify_archive(path)
        with fopen_read(comm, path) as r:
            doc = _read_header_sections(r)
            if doc.get("format") != mf.SHARDED_FORMAT:
                return _restore_from_reader(r, doc, like, pf)
        # Sharded set: the manifest file holds no payloads — close it and
        # resolve the per-shard archives (deterministic collective opens).
        from repro.checkpoint import sharding as _sharding
        return _sharding.restore_sharded(path, doc, like, comm=comm,
                                         prefetch_bytes=prefetch_bytes,
                                         verify=vfy)


def _restore_from_reader(r: ScdaReader, doc: Dict[str, Any], like,
                         pf: int):
    """The flat-checkpoint restore body (reader already past the
    manifest) — what :func:`restore` runs once the doc turned out not to
    be a sharded-set manifest."""
    step = doc.get("step")
    chained = bool(doc.get("delta"))
    if chained:
        from repro.checkpoint import delta as _delta
    by_name: Dict[str, Any] = {}
    for i, spec_ in enumerate(doc["leaves"]):
        by_name[spec_["name"]] = (i, spec_)

    if like is None:
        out: Dict[str, Any] = {}
        if chained:
            # Incremental checkpoint: every leaf resolves through the
            # manifest chain (prefetch engine per archive; pf<=0 is
            # the serial oracle inside the resolver too).
            _adopt_sidecar(r)
            wanted = [(spec_["name"], i, spec_, None)
                      for i, spec_ in enumerate(doc["leaves"])]
            out = (_delta.restore_chained(r, doc, wanted, pf)
                   if wanted else {})
        elif pf > 0 and doc["leaves"]:
            _adopt_sidecar(r)
            wanted = [(spec_["name"], i, spec_, None)
                      for i, spec_ in enumerate(doc["leaves"])]
            out = _restore_pipelined(r, wanted, pf)
        else:
            # Serial oracle: the forward walk touches every byte in
            # file order, one section at a time.
            for spec_ in doc["leaves"]:
                hdr = r.read_section_header()
                _check_leaf_header(hdr, spec_)
                out[spec_["name"]] = _read_leaf_full(r, hdr, spec_)
        for name, value in doc["aux"].items():
            out[name] = value
        return _unflatten_names(out), step

    named, treedef = flatten_named(like)
    targets = {n: v for n, v in named}
    missing = [n for n in targets
               if n not in by_name and n not in doc["aux"]]
    if missing:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"leaves missing from checkpoint: {missing[:5]}"
                        f"{'…' if len(missing) > 5 else ''}")
    _adopt_sidecar(r)
    if chained:
        wanted = [(name,) + by_name[name] + (targets[name],)
                  for name in targets if name in by_name]
        values = (_delta.restore_chained(r, doc, wanted, pf)
                  if wanted else {})
    elif pf > 0:
        wanted = [(name,) + by_name[name] + (targets[name],)
                  for name in targets if name in by_name]
        values = _restore_pipelined(r, wanted, pf)
    else:
        values = {}
        for name in targets:
            if name not in by_name:
                continue  # aux leaf
            i, spec_ = by_name[name]
            hdr = r.open_section(mf.leaf_user_string(i))
            _check_leaf_header(hdr, spec_)
            values[name] = _read_leaf_to_target(r, hdr, spec_,
                                                targets[name])
    for name in targets:
        if name in doc["aux"]:
            values[name] = doc["aux"][name]
    leaves_out = [values[n] for n, _ in named]
    return jax.tree_util.tree_unflatten(treedef, leaves_out), step


def restore_leaf(path: str, name: str, like=None, *,
                 comm: Optional[Communicator] = None,
                 prefetch_bytes: Optional[int] = None,
                 verify: Optional[bool] = None):
    """Load ONE leaf from a checkpoint without touching the rest.

    The lazy-restore workload §1 motivates: seek straight to the leaf's
    section (sidecar index or one header scan), read only its bytes —
    for compressed leaves only the chunks overlapping the target shards,
    inflated on the codec pool while later chunk preads are in flight
    (``prefetch_bytes`` as in :func:`restore`).
    ``like`` optionally gives a target (``jax.ShapeDtypeStruct`` with
    ``.sharding`` or a concrete array) to place the leaf onto; with
    ``like=None`` a numpy array is returned.  Aux (non-array) leaves are
    returned from the manifest directly.
    """
    comm = comm or SerialComm()
    pf = _effective_prefetch(prefetch_bytes)
    vfy = _effective_verify(verify)
    with _trace.span("restore_leaf", "ckpt", path=path, leaf=name):
        if vfy:
            _verify_archive(path)
        with fopen_read(comm, path) as r:
            doc = _read_header_sections(r)
            if doc.get("format") == mf.SHARDED_FORMAT:
                sharded = doc
            else:
                return _restore_leaf_from_reader(r, doc, name, like, pf)
        from repro.checkpoint import sharding as _sharding
        return _sharding.restore_leaf_sharded(path, sharded, name, like,
                                              comm=comm,
                                              prefetch_bytes=prefetch_bytes,
                                              verify=vfy)


def _restore_leaf_from_reader(r: ScdaReader, doc: Dict[str, Any],
                              name: str, like, pf: int):
    for i, spec_ in enumerate(doc["leaves"]):
        if spec_["name"] != name:
            continue
        _adopt_sidecar(r)
        if doc.get("delta"):
            from repro.checkpoint import delta as _delta
            return _delta.restore_chained(
                r, doc, [(name, i, spec_, like)], pf)[name]
        if pf > 0:
            return _restore_pipelined(
                r, [(name, i, spec_, like)], pf)[name]
        hdr = r.open_section(mf.leaf_user_string(i))
        _check_leaf_header(hdr, spec_)
        if like is None:
            return _read_leaf_full(r, hdr, spec_)
        return _read_leaf_to_target(r, hdr, spec_, like)
    if name in doc["aux"]:
        return doc["aux"][name]
    raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                    f"leaf {name!r} not in checkpoint")


def _check_leaf_header(hdr, spec_) -> None:
    if spec_.get("store") == "delta":
        # Delta-stored leaves hold only their present chunk subset and
        # are resolved by the chain resolver, never by the flat readers.
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"leaf {spec_['name']}: delta-stored leaf outside "
                        f"the chain resolver")
    if spec_["compressed"]:
        if hdr.type != "V" or hdr.N != len(layout.chunk_sizes(
                spec_["nbytes"], spec_["chunk_bytes"])):
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"leaf {spec_['name']}: bad compressed section")
    else:
        if hdr.type != "A" or hdr.N != spec_["nbytes"] or hdr.E != 1:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"leaf {spec_['name']}: bad array section "
                            f"({hdr.type} N={hdr.N} E={hdr.E})")


# --------------------------------------------------------------------------
# The overlapped restore engine's checkpoint scheduler
# --------------------------------------------------------------------------

class _Unit:
    """One assembly unit of a leaf: a distinct shard extent (or the whole
    leaf) with its contiguous runs and destination host buffer.

    The buffer is uninitialized (``np.empty``): every byte is covered by
    a run (raw leaves) or a chunk span (compressed leaves), and a 64 MiB
    ``bytearray`` would pay a pure-overhead memset on the hot path.
    """

    __slots__ = ("runs", "shard_shape", "arr", "buf")

    def __init__(self, runs, shard_shape, nbytes: int) -> None:
        self.runs = runs
        self.shard_shape = shard_shape
        self.arr = np.empty(nbytes, np.uint8)
        self.buf = memoryview(self.arr)


def _shard_shape(index, shape) -> Tuple[int, ...]:
    return tuple(sl.indices(dim)[1] - sl.indices(dim)[0]
                 for sl, dim in zip(index, shape)) if shape else ()


def _leaf_layout(name: str, spec_, target) -> Dict[str, Any]:
    """Target-side layout of one leaf: dtype/shape/sharding plus the
    assembly units (distinct shard extents, or the whole leaf) with
    their run decompositions and host buffers.

    Shared by the flat restore scheduler and the delta chain resolver —
    the *destination* of a leaf is the same regardless of which
    archive(s) its bytes come from.
    """
    dtype = mf.dtype_from_name(spec_["dtype"])
    shape = tuple(spec_["shape"])
    sharding = None
    if target is not None:
        t_shape = tuple(getattr(target, "shape", np.shape(target)))
        if t_shape != shape:
            raise ScdaError(
                ScdaErrorCode.ARG_SEQUENCE,
                f"leaf {spec_['name']}: target shape {t_shape} != "
                f"checkpoint shape {shape}")
        sharding = getattr(target, "sharding", None)
    units: List[_Unit] = []
    per_device: List[Tuple[Any, int]] = []
    if sharding is None:
        runs = [(0, 0, spec_["nbytes"])] if spec_["nbytes"] else []
        units.append(_Unit(runs, shape, spec_["nbytes"]))
    else:
        itemsize = np.dtype(dtype).itemsize
        by_extent: Dict[Tuple, int] = {}
        for device, index in \
                sharding.addressable_devices_indices_map(shape).items():
            key = _index_key(index, shape)
            if key not in by_extent:
                runs = layout.shard_runs(shape, index, itemsize)
                sshape = _shard_shape(index, shape)
                nbytes = (int(np.prod(sshape, dtype=np.int64)) * itemsize
                          if sshape else itemsize)
                by_extent[key] = len(units)
                units.append(_Unit(runs, sshape, nbytes))
            per_device.append((device, by_extent[key]))
    return {"name": name, "spec": spec_, "target": target,
            "dtype": dtype, "shape": shape, "sharding": sharding,
            "units": units, "per_device": per_device, "pending": 0}


def _restore_pipelined(r: ScdaReader, wanted, prefetch_bytes: int) \
        -> Dict[str, Any]:
    """Restore ``wanted`` leaves through the overlapped engine.

    ``wanted``: list of ``(name, manifest_index, spec, target)`` with
    ``target`` a ShapeDtypeStruct/array (placement honored) or None
    (plain numpy out).  One index walk plans every leaf: raw leaves read
    straight into their shard buffers (zero-copy scatter reads),
    compressed leaves read only the chunks overlapping their shards and
    inflate them on the codec pool.  All plans are sorted by file offset
    so consumption sweeps the archive front to back while prefetch runs
    ``prefetch_bytes`` ahead; fully consumed extents are released
    (``DONTNEED``).  Byte-identical to the serial walk by construction —
    only the schedule changes, never the bytes.
    """
    idx = _resolve_index(r)
    backend = r._backend
    leaves: List[Dict[str, Any]] = []
    items: List[ReadItem] = []
    for leaf_pos, (name, i, spec_, target) in enumerate(wanted):
        user = mf.leaf_user_string(i)
        sec = idx.find(user)
        if sec < 0:
            salvage = getattr(idx, "_salvage_error", None)
            if salvage is not None:
                raise salvage
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"no section with user string {user!r} "
                            f"(occurrence 0)")
        e = idx.entries[sec]
        r.verify_index_entry(sec, e)
        _check_leaf_header(e.header(), spec_)
        leaf = _leaf_layout(name, spec_, target)
        units = leaf["units"]
        if spec_["compressed"]:
            chunk = spec_["chunk_bytes"]
            csizes = r._parse_entries(e.v_entries_start, 0, e.N, b"E")
            usizes = r._parse_entries(e.entries_start, 0, e.N, b"U")
            offs = partition.offsets(csizes)
            for ui, unit in enumerate(units):
                needed = layout.chunks_for_runs(unit.runs, chunk)
                if not needed:
                    continue
                items.append(ReadItem(
                    (leaf_pos, ui, needed),
                    [(e.v_data_start + offs[c], csizes[c]) for c in needed],
                    inflate=True,
                    expected_sizes=[usizes[c] for c in needed]))
                leaf["pending"] += 1
        else:
            for ui, unit in enumerate(units):
                if not unit.runs:
                    continue
                view = memoryview(unit.buf)
                items.append(ReadItem(
                    (leaf_pos, ui, None),
                    [(e.data_start + g, n) for g, _, n in unit.runs],
                    dst=[view[loc:loc + n] for _, loc, n in unit.runs]))
                leaf["pending"] += 1
        leaves.append(leaf)

    items.sort(key=lambda it: it.start())
    values: Dict[str, Any] = {}
    for leaf in leaves:  # zero-byte leaves have nothing in flight
        if leaf["pending"] == 0:
            values[leaf["name"]] = _finalize_leaf(leaf)
    for key, res in run_pipeline(backend, items, prefetch_bytes):
        leaf_pos, ui, needed = key
        leaf = leaves[leaf_pos]
        unit = leaf["units"][ui]
        if needed is not None:  # compressed: scatter chunks into the unit
            if leaf["sharding"] is None:
                # Whole-leaf unit: mirror the serial _read_leaf_full
                # exactly — chunks concatenate in element order and the
                # total must equal the manifest size, with no boundary
                # assumption (a foreign archive whose chunk sizes stray
                # from the layout geometry still joins to the same
                # bytes, or fails with the same error, as the oracle).
                _fill_joined(res, unit.arr, leaf["spec"])
            else:
                _scatter_chunks_np(unit.runs, dict(zip(needed, res)),
                                   leaf["spec"]["chunk_bytes"], unit.arr)
        leaf["pending"] -= 1
        if leaf["pending"] == 0:
            values[leaf["name"]] = _finalize_leaf(leaf)
    return values


def _finalize_leaf(leaf: Dict[str, Any]):
    """Assemble a completed leaf from its unit buffers (host → device)."""
    dtype, shape = leaf["dtype"], leaf["shape"]
    if leaf["sharding"] is None:
        return leaf["units"][0].arr.view(dtype).reshape(shape)
    arrays = [
        jax.device_put(
            leaf["units"][ui].arr.view(dtype)
            .reshape(leaf["units"][ui].shard_shape), device)
        for device, ui in leaf["per_device"]]
    return jax.make_array_from_single_device_arrays(
        shape, leaf["sharding"], arrays)


def _fill_joined(chunks: List[bytes], arr: np.ndarray, spec_) -> None:
    """Serial-oracle assembly for a whole-leaf unit: the inflated chunks
    are concatenated in element order and the total checked against the
    manifest — :func:`_read_leaf_full`'s ``b"".join`` + size check,
    without materializing the join."""
    total = sum(map(len, chunks))
    if total != spec_["nbytes"]:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"leaf {spec_['name']}: {total} bytes, "
                        f"manifest says {spec_['nbytes']}")
    pos = 0
    for c in chunks:
        if len(c):
            arr[pos:pos + len(c)] = np.frombuffer(c, np.uint8)
            pos += len(c)


def _short_chunk(ci: int, have: int, want: int) -> ScdaError:
    return ScdaError(
        ScdaErrorCode.CORRUPT_CHECKSUM,
        f"chunk {ci} holds {have} bytes, layout needs {want} — inflated "
        f"size disagrees with the manifest chunk geometry")


def _scatter_chunks(runs, chunks: Dict[int, bytes], chunk_bytes: int,
                    buf) -> None:
    """Copy the overlapping spans of inflated ``chunks`` into ``buf``
    (any mutable byte buffer: bytearray or a uint8 memoryview).

    A chunk shorter than the manifest geometry implies (a corrupt or
    foreign archive whose U-entries disagree with ``chunk_bytes``) is a
    CORRUPT_CHECKSUM :class:`ScdaError`, never a silent short copy.
    One implementation serves both paths — ``np.frombuffer`` wraps any
    writable buffer — so the serial and pipelined scatters cannot
    diverge.
    """
    _scatter_chunks_np(runs, chunks, chunk_bytes,
                       np.frombuffer(buf, np.uint8))


def _scatter_chunks_np(runs, chunks: Dict[int, bytes], chunk_bytes: int,
                       arr: np.ndarray) -> None:
    """:func:`_scatter_chunks` for a uint8 ndarray destination: big spans
    copy through numpy (which drops the GIL), so the engine's assembly
    does not stall the codec pool's decode slices."""
    for goff, loff, n in runs:
        pos = 0
        while pos < n:
            ci, off = divmod(goff + pos, chunk_bytes)
            take = min(n - pos, chunk_bytes - off)
            data = chunks[ci]
            if len(data) < off + take:
                raise _short_chunk(ci, len(data), off + take)
            arr[loff + pos:loff + pos + take] = \
                np.frombuffer(data, np.uint8, take, off)
            pos += take


def _read_leaf_full(r: ScdaReader, hdr, spec_) -> np.ndarray:
    dtype = mf.dtype_from_name(spec_["dtype"])
    if spec_["compressed"]:
        sizes = layout.chunk_sizes(spec_["nbytes"], spec_["chunk_bytes"])
        n = len(sizes)
        raw = b"".join(r.read_varray_elements(list(range(n))))
        r.skip_data()
    else:
        raw = b"".join(r.read_array_windows([(0, spec_["nbytes"])], 1))
        r.skip_data()
    if len(raw) != spec_["nbytes"]:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"leaf {spec_['name']}: {len(raw)} bytes, "
                        f"manifest says {spec_['nbytes']}")
    arr = np.frombuffer(raw, dtype=dtype).reshape(spec_["shape"])
    return arr.copy()


def _read_leaf_to_target(r: ScdaReader, hdr, spec_, target):
    """Assemble the leaf under the target's sharding (any mesh)."""
    dtype = mf.dtype_from_name(spec_["dtype"])
    shape = tuple(spec_["shape"])
    t_shape = tuple(getattr(target, "shape", np.shape(target)))
    if tuple(t_shape) != shape:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"leaf {spec_['name']}: target shape {t_shape} != "
                        f"checkpoint shape {shape}")
    sharding = getattr(target, "sharding", None)
    if sharding is None:
        return _read_leaf_full(r, hdr, spec_)

    # One host buffer per *distinct* addressable shard extent.
    device_map = sharding.addressable_devices_indices_map(shape)
    shard_arrays: Dict[Tuple, np.ndarray] = {}
    per_device = []
    for device, index in device_map.items():
        key = _index_key(index, shape)
        if key not in shard_arrays:
            shard_arrays[key] = _read_shard(r, spec_, index, shape, dtype)
        per_device.append((device, shard_arrays[key]))
    r.skip_data()
    arrays = [jax.device_put(arr, device) for device, arr in per_device]
    return jax.make_array_from_single_device_arrays(shape, sharding, arrays)


def _index_key(index, shape) -> Tuple:
    out = []
    for sl, dim in zip(index, shape):
        start, stop, _ = sl.indices(dim)
        out.append((start, stop))
    return tuple(out)


def _read_shard(r: ScdaReader, spec_, index, shape, dtype) -> np.ndarray:
    itemsize = np.dtype(dtype).itemsize
    runs = layout.shard_runs(shape, index, itemsize)
    shard_shape = _shard_shape(index, shape)
    buf = bytearray(int(np.prod(shard_shape, dtype=np.int64)) * itemsize
                    if shard_shape else itemsize)
    if spec_["compressed"]:
        _fill_from_chunks(r, spec_, runs, buf)
    else:
        if runs:
            got = r.read_array_windows([(g, n) for g, _, n in runs], 1)
            for (g, loff, n), raw in zip(runs, got):
                buf[loff:loff + n] = raw
    arr = np.frombuffer(bytes(buf), dtype=dtype)
    return arr.reshape(shard_shape)


def _fill_from_chunks(r: ScdaReader, spec_, runs, buf: bytearray) -> None:
    """Selective chunk reads: only chunks overlapping this shard's runs."""
    chunk = spec_["chunk_bytes"]
    needed = layout.chunks_for_runs(runs, chunk)
    if not needed:
        return
    chunks = dict(zip(needed, r.read_varray_elements(needed)))
    _scatter_chunks(runs, chunks, chunk, buf)


def _unflatten_names(flat: Dict[str, Any]):
    """Rebuild a nested dict from 'a/b/c' names (like=None restores)."""
    root: Dict[str, Any] = {}
    for name, value in flat.items():
        parts = name.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root
