"""Checkpoint/restart on the scda format — the paper's technique as a
first-class framework feature.

    from repro.checkpoint import CheckpointManager, save, restore

    mgr = CheckpointManager("/ckpts/run7", keep=3)
    state, start = mgr.restore_or_init(init_fn, like=abstract_state)
    for step in range(start + 1, total):
        state = train_step(state, batch)
        if step % 500 == 0:
            mgr.save(step, state)          # async, atomic, serial-equivalent
"""
from repro.checkpoint.delta import (verify_chain, squash, checkpoint_diff)
from repro.checkpoint.layout import (shard_runs, chunk_sizes,
                                     chunks_for_runs, runs_cover_exactly)
from repro.checkpoint.manifest import (MANIFEST_USER_STRING,
                                       STATUS_USER_STRING,
                                       SHARDS_FILE_USER_STRING, content_id)
from repro.checkpoint.sharding import (save_sharded, read_sharded_manifest,
                                       verify_set, assign_shards,
                                       shard_file, is_shard_name)
from repro.checkpoint.pytree_io import (save, restore, restore_leaf,
                                        read_manifest, flatten_named,
                                        leaf_name, DEFAULT_CHUNK_BYTES)
from repro.checkpoint.manager import CheckpointManager, snapshot_to_host

__all__ = [
    "shard_runs", "chunk_sizes", "chunks_for_runs", "runs_cover_exactly",
    "MANIFEST_USER_STRING", "STATUS_USER_STRING", "SHARDS_FILE_USER_STRING",
    "content_id", "save", "restore", "restore_leaf", "read_manifest",
    "flatten_named", "leaf_name", "DEFAULT_CHUNK_BYTES", "CheckpointManager",
    "snapshot_to_host", "verify_chain", "squash", "checkpoint_diff",
    "save_sharded", "read_sharded_manifest", "verify_set", "assign_shards",
    "shard_file", "is_shard_name",
]
