"""Erasure-coded shard sets — parity shards, reconstruction, rebuild.

A sharded checkpoint (``repro.checkpoint.sharding``) is N independent
scda archives pinned by a manifest; lose any one shard and the whole
set used to be gone.  This module layers an m-erasure code over the set
without touching the format: each parity shard is itself a byte-valid
scda file computed over the *raw file byte streams* of the N data
shards, zero-padded to the longest shard:

    F  header (user string "repro ckpt-parity")
    I  "scda-ckpt status"      — same human-readable step line
    B  "scda-parity meta"      — JSON: code geometry, per-shard sizes,
                                 payload CRC32
    A  "scda-parity payload"   — the parity byte stream (raw; parity
                                 bytes are high-entropy, §3 encoding
                                 would only burn CPU)

Codes: ``m=1`` is plain XOR; ``m=2`` is a 2-row GF(2^8) Reed–Solomon
Vandermonde code (generator α=2, polynomial 0x11d) — parity row j holds
``P_j = Σ_i α^(i·j) · D_i``, vectorized with numpy through per-constant
256-entry multiplication tables.  Coding over whole file streams (not
logical chunks) is what makes ``repair --rebuild`` byte-identical and
range reconstruction trivial: byte b of a lost shard depends only on
byte b of every survivor.

Degraded reads never trust reconstruction blindly: a reconstructed
shard still flows through the ordinary content-id pinning and chunk CRC
checks downstream, so rotten parity or a rotten survivor fails loudly
instead of assembling silently wrong tensors.

Knobs: ``CheckpointManager(parity=m)`` / ``save(..., parity=m)`` or
``REPRO_SCDA_PARITY=m`` (0 = no parity; parity without sharding is a
no-op).  Module-level imports stay jax-free, like sharding.py.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import manifest as mf
from repro.core import trace as _trace
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.io_backend import FileBackend, fsync_dir, replace_file
from repro.core.reader import ScdaReader, fopen_read
from repro.core.writer import fopen_write

#: ``REPRO_SCDA_PARITY``: default parity shard count for sharded saves.
PARITY_ENV = "REPRO_SCDA_PARITY"

PARITY_FILE_USER_STRING = b"repro ckpt-parity"
PARITY_META_USER_STRING = b"scda-parity meta"
PARITY_PAYLOAD_USER_STRING = b"scda-parity payload"
PARITY_FORMAT = "repro-scda-parity"
PARITY_VERSION = 1

#: Max parity shards (XOR at 1, 2-row RS at 2).
MAX_PARITY = 2

#: ``<stem>-p<j>of<m>.scda`` — what a parity file is named.  Cannot
#: collide with data shards (``-s<k>of<n>``) or the step pattern.
_PARITY_RE = re.compile(r"^(?P<stem>.+)-p(?P<j>\d+)of(?P<m>\d+)\.scda$")

_STREAM_CHUNK = 4 << 20


def parity_default() -> int:
    """Resolve the ``REPRO_SCDA_PARITY`` knob (0 / unset = no parity)."""
    try:
        return max(0, int(os.environ.get(PARITY_ENV, "0")))
    except ValueError:
        return 0


def parity_file(path: str, j: int, m: int) -> str:
    """Path of parity shard ``j`` of ``m`` for the manifest at ``path``."""
    stem = path[:-len(".scda")] if path.endswith(".scda") else path
    width = max(2, len(str(m - 1)), len(str(m)))
    return f"{stem}-p{j:0{width}d}of{m:0{width}d}.scda"


def is_parity_name(name: str) -> Optional[Tuple[str, int, int]]:
    """``(manifest_name, j, m)`` if ``name`` looks like a parity file,
    else None — the retention sweep uses this to spot orphaned parity."""
    g = _PARITY_RE.match(name)
    if not g:
        return None
    return (g.group("stem") + ".scda", int(g.group("j")), int(g.group("m")))


def check_geometry(shards: int, parity: int) -> None:
    """Validate a requested code geometry before any bytes move."""
    if parity < 0 or parity > MAX_PARITY:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"parity={parity}: supported 0..{MAX_PARITY} "
                        f"(XOR at 1, GF(2^8) RS at 2)")
    if parity >= 2 and shards > 255:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"parity=2 needs distinct GF(2^8) code points: "
                        f"shards={shards} > 255")


# --------------------------------------------------------------------------
# GF(2^8) arithmetic — generator α=2, polynomial 0x11d, table-driven
# --------------------------------------------------------------------------

_GF_EXP: Optional[np.ndarray] = None
_GF_LOG: Optional[np.ndarray] = None
_MUL_TABLES: Dict[int, np.ndarray] = {}


def _gf_tables() -> Tuple[np.ndarray, np.ndarray]:
    global _GF_EXP, _GF_LOG
    if _GF_EXP is None:
        exp = np.zeros(512, dtype=np.uint8)
        log = np.zeros(256, dtype=np.int32)
        x = 1
        for i in range(255):
            exp[i] = x
            log[x] = i
            x <<= 1
            if x & 0x100:
                x ^= 0x11D
        exp[255:510] = exp[0:255]
        _GF_EXP, _GF_LOG = exp, log
    return _GF_EXP, _GF_LOG


def gf_pow_alpha(i: int) -> int:
    """α^i in GF(2^8)."""
    exp, _ = _gf_tables()
    return int(exp[i % 255])


def gf_mul(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    exp, log = _gf_tables()
    return int(exp[int(log[a]) + int(log[b])])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("GF(2^8) inverse of 0")
    exp, log = _gf_tables()
    return int(exp[255 - int(log[a])])


def _mul_table(c: int) -> np.ndarray:
    """256-entry lookup table for ``c · v`` — ``table[arr]`` vectorizes
    constant multiplication over a whole byte stream."""
    t = _MUL_TABLES.get(c)
    if t is None:
        v = np.arange(256, dtype=np.uint8)
        if c == 0:
            t = np.zeros(256, dtype=np.uint8)
        elif c == 1:
            t = v.copy()
        else:
            exp, log = _gf_tables()
            t = np.zeros(256, dtype=np.uint8)
            t[1:] = exp[int(log[c]) + log[1:]]
        _MUL_TABLES[c] = t
    return t


def _mul_into(acc: np.ndarray, c: int, data) -> None:
    """acc ^= c · data, vectorized (``data``: uint8 array or buffer)."""
    if not isinstance(data, np.ndarray):
        data = np.frombuffer(data, dtype=np.uint8)
    if c == 0 or data.size == 0:
        return
    if c == 1:
        acc[:len(data)] ^= data
    else:
        acc[:len(data)] ^= _mul_table(c)[data]


def _coeff(i: int, j: int) -> int:
    """Code coefficient of data shard ``i`` in parity row ``j``."""
    return 1 if j == 0 else gf_pow_alpha(i * j)


# --------------------------------------------------------------------------
# Parity emission (save path)
# --------------------------------------------------------------------------

def _canonical_meta(meta: Dict[str, Any]) -> bytes:
    return json.dumps(meta, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def parity_id(meta: Dict[str, Any]) -> str:
    """Deterministic 128-bit id of a parity shard — hashed over the
    canonical meta JSON (which pins the payload via its CRC32), so a
    cheap meta-block read verifies a parity file against the manifest."""
    return hashlib.blake2b(_canonical_meta(meta),
                           digest_size=16).hexdigest()


def _read_padded(f, offset: int, want: int, cl: int) -> np.ndarray:
    """``cl`` bytes of a data-shard stream at ``offset``: file bytes up
    to ``want``, zero-padded to the coding length."""
    a = np.zeros(cl, dtype=np.uint8)
    if want > 0:
        f.seek(offset)
        buf = f.read(want)
        if len(buf) < want:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_TRUNCATED,
                f"{f.name}: EOF at {offset + len(buf)}, wanted "
                f"{offset + want} while computing parity",
                offset=offset + len(buf))
        a[:want] = np.frombuffer(buf, dtype=np.uint8)
    return a


def write_parity_files(path: str, shard_recs: List[Dict[str, Any]],
                       parity: int, *, step: Optional[int] = None,
                       tmp_suffix: str = "", in_suffix: Optional[str] = None,
                       sync: bool = True) -> Dict[str, Any]:
    """Compute and write ``parity`` parity shards over the (already
    written) data shard files of the set at ``path``; returns the
    manifest ``parity`` record.

    One streaming pass over the shard files per parity row (m ≤ 2, and
    the second pass rides the page cache), peak memory one coded stream
    (max shard size) plus a 4 MiB window per shard.
    """
    check_geometry(len(shard_recs), parity)
    if in_suffix is None:
        in_suffix = tmp_suffix  # a save reads the not-yet-renamed shards
    base = os.path.dirname(path)
    names = [r["file"] for r in shard_recs]
    sizes = [int(r["bytes"]) for r in shard_recs]
    length = max(sizes) if sizes else 0
    code = "xor" if parity == 1 else "rs8"
    files: List[Dict[str, Any]] = []
    _tc = _trace.collector()
    _t0 = _tc.now() if _tc is not None else 0
    for j in range(parity):
        chunks: List[bytes] = []
        crc = 0
        fhs = [open(os.path.join(base, n) + in_suffix, "rb")
               for n in names]
        try:
            for off in range(0, length, _STREAM_CHUNK):
                cl = min(_STREAM_CHUNK, length - off)
                acc = np.zeros(cl, dtype=np.uint8)
                for i, fh in enumerate(fhs):
                    want = max(0, min(sizes[i], off + cl) - off)
                    _mul_into(acc, _coeff(i, j),
                              _read_padded(fh, off, want, cl)[:want])
                chunk = acc.tobytes()
                crc = zlib.crc32(chunk, crc)
                chunks.append(chunk)
        finally:
            for fh in fhs:
                fh.close()
        meta = {"format": PARITY_FORMAT, "version": PARITY_VERSION,
                "code": code, "n": len(names), "m": parity, "j": j,
                "length": length, "sizes": sizes, "shards": names,
                "crc32": crc & 0xFFFFFFFF, "step": step}
        pid = parity_id(meta)
        ppath = parity_file(path, j, parity)
        with fopen_write(None, ppath + tmp_suffix,
                         user_string=PARITY_FILE_USER_STRING,
                         sync=sync) as f:
            f.write_inline(mf.STATUS_USER_STRING, mf.status_inline(step))
            f.write_block(PARITY_META_USER_STRING, _canonical_meta(meta))
            windows, pos = [], 0
            for c in chunks:
                windows.append((pos, c))
                pos += len(c)
            f.write_array_windows(PARITY_PAYLOAD_USER_STRING, windows,
                                  length, 1)
        files.append({"file": os.path.basename(ppath), "id": pid,
                      "bytes": int(os.path.getsize(ppath + tmp_suffix))})
    if _tc is not None:
        _tc.end("parity_encode", "ckpt", _t0,
                {"path": path, "code": code, "n": len(shard_recs),
                 "m": parity, "bytes": length * parity})
    return {"code": code, "m": parity, "length": length, "files": files}


def set_parity_paths(path: str, parity: int,
                     tmp_suffix: str = "") -> List[str]:
    """Every parity file a ``parity=m`` save writes for the set at
    ``path`` (tmp-sweep / commit bookkeeping)."""
    return [parity_file(path, j, parity) + tmp_suffix
            for j in range(max(0, int(parity)))]


# --------------------------------------------------------------------------
# Reading parity files back
# --------------------------------------------------------------------------

def read_parity_meta(path: str) -> Dict[str, Any]:
    """The meta document of a parity shard (no payload reads)."""
    with fopen_read(None, path) as r:
        meta, _, _ = _parity_sections(r)
    return meta


def _parity_sections(r: ScdaReader) -> Tuple[Dict[str, Any], int, int]:
    """(meta, payload_data_start, payload_bytes) of an open parity file."""
    if r.user_string != PARITY_FILE_USER_STRING:
        raise ScdaError(
            ScdaErrorCode.CORRUPT_ENCODING,
            f"{r.path}: not a parity shard (file user string "
            f"{r.user_string!r})")
    r.open_section(PARITY_META_USER_STRING)
    raw = r.read_block_data()
    try:
        meta = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, ValueError) as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"{r.path}: parity meta is not JSON: {e}") from e
    if meta.get("format") != PARITY_FORMAT \
            or meta.get("version") != PARITY_VERSION:
        raise ScdaError(
            ScdaErrorCode.CORRUPT_ENCODING,
            f"{r.path}: unknown parity format "
            f"{meta.get('format')!r} v{meta.get('version')!r}")
    idx = r.index()
    i = idx.find(PARITY_PAYLOAD_USER_STRING)
    if i < 0:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"{r.path}: no parity payload section")
    e = idx.entries[i]
    if e.kind != "A" or e.E != 1 or e.N != meta.get("length"):
        raise ScdaError(
            ScdaErrorCode.CORRUPT_ENCODING,
            f"{r.path}: parity payload is {e.kind} N={e.N} E={e.E}, "
            f"meta says raw A N={meta.get('length')} E=1")
    return meta, e.data_start, e.N * e.E


def verify_parity_file(path: str, rec: Dict[str, Any],
                       deep: bool = False) -> List[str]:
    """Problems of one parity file against its manifest record.  Cheap
    pass: structure + meta id.  ``deep`` additionally CRCs the payload."""
    problems: List[str] = []
    try:
        size = os.path.getsize(path)
    except OSError:
        return ["missing parity file"]
    if size != rec.get("bytes"):
        problems.append(f"{size} bytes on disk, manifest recorded "
                        f"{rec.get('bytes')}")
    try:
        with fopen_read(None, path) as r:
            meta, data_start, nbytes = _parity_sections(r)
            got = parity_id(meta)
            if got != rec.get("id"):
                problems.append(
                    f"parity id {got} != recorded {rec.get('id')} — the "
                    f"parity file was rewritten since the set was saved")
            elif deep:
                crc = 0
                for off in range(0, nbytes, _STREAM_CHUNK):
                    n = min(_STREAM_CHUNK, nbytes - off)
                    crc = zlib.crc32(
                        r._backend.pread(data_start + off, n), crc)
                if crc & 0xFFFFFFFF != meta.get("crc32"):
                    problems.append(
                        f"payload CRC32 {crc & 0xFFFFFFFF:#010x} != "
                        f"recorded {meta.get('crc32'):#010x}")
    except (ScdaError, OSError, ValueError) as e:
        problems.append(str(e))
    return problems


# --------------------------------------------------------------------------
# Reconstruction
# --------------------------------------------------------------------------

def warn_degraded(set_name: str, lost: List[str], via: List[str]) -> None:
    """The loud one-line degraded-read warning.

    Routed through :func:`repro.core.trace.warn` — logging-backed (so
    tests and applications can capture or silence it) and rate-limited
    per (set, lost-file) key so a restore that reconstructs a lost shard
    leaf-by-leaf warns once, not once per read."""
    _trace.warn(
        f"DEGRADED READ of {set_name!r}: reconstructing "
        f"{', '.join(sorted(lost))} from surviving shards + "
        f"{', '.join(via)}",
        key=("degraded", set_name, tuple(sorted(lost))))
    _trace.event("degraded_read", "ckpt", set=set_name,
                 lost=",".join(sorted(lost)), via=",".join(via))


class SetReconstructor:
    """Byte-range reconstruction of lost data shards of one set.

    Classifies every data and parity file of the set as usable or lost
    (missing, wrong size, or — for parity — a meta id that no longer
    matches the manifest), refuses loudly when the erasure budget is
    exceeded, and serves ``read(name, offset, n)`` for any lost data
    shard by solving the (≤2)-erasure linear system over exactly the
    requested byte range of every survivor.
    """

    def __init__(self, path: str, doc: Dict[str, Any],
                 lost: Tuple[str, ...] = ()) -> None:
        self.path = path
        self.dir = os.path.dirname(os.path.abspath(path))
        prec = doc.get("parity")
        if not prec:
            raise ScdaError(
                ScdaErrorCode.FS_OPEN,
                f"{os.path.basename(path)}: set has no parity shards — "
                f"lost shards are unrecoverable")
        self.shards = doc.get("shards", [])
        self.names = [s["file"] for s in self.shards]
        self.sizes = [int(s["bytes"]) for s in self.shards]
        self.length = int(prec.get("length", 0))
        self.lost: set = set(lost)
        self._data: Dict[int, FileBackend] = {}
        for i, srec in enumerate(self.shards):
            name = srec["file"]
            if name in self.lost:
                continue
            spath = os.path.join(self.dir, name)
            try:
                if os.path.getsize(spath) != self.sizes[i]:
                    self.lost.add(name)
            except OSError:
                self.lost.add(name)
        unknown = self.lost - set(self.names)
        if unknown:
            raise ScdaError(
                ScdaErrorCode.ARG_SEQUENCE,
                f"not data shards of this set: {sorted(unknown)}")
        # Usable parity rows, cheap-verified against the manifest record.
        self.parity_rows: List[Tuple[int, ScdaReader, int]] = []
        self.lost_parity: List[str] = []
        for j, rec in enumerate(prec.get("files", [])):
            ppath = os.path.join(self.dir, rec.get("file", ""))
            try:
                r = fopen_read(None, ppath)
            except (ScdaError, OSError):
                self.lost_parity.append(rec.get("file", ""))
                continue
            try:
                meta, data_start, _ = _parity_sections(r)
                if parity_id(meta) != rec.get("id") \
                        or meta.get("j") != j \
                        or meta.get("sizes") != self.sizes \
                        or meta.get("length") != self.length:
                    raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                                    "parity meta mismatch")
            except (ScdaError, OSError, ValueError):
                r.close()
                self.lost_parity.append(rec.get("file", ""))
                continue
            self.parity_rows.append((j, r, data_start))
        n_lost = len(self.lost)
        if n_lost > len(self.parity_rows):
            self.close()
            raise ScdaError(
                ScdaErrorCode.CORRUPT_CHECKSUM,
                f"{os.path.basename(path)}: {n_lost} data shard(s) lost "
                f"({', '.join(sorted(self.lost))}) but only "
                f"{len(self.parity_rows)} usable parity shard(s) — "
                f"unrecoverable")
        self.via = [f"parity row {j}" for j, _, _ in
                    self.parity_rows[:max(1, n_lost)]]

    def shard_size(self, name: str) -> int:
        return self.sizes[self.names.index(name)]

    def _data_backend(self, i: int) -> FileBackend:
        b = self._data.get(i)
        if b is None:
            b = FileBackend(os.path.join(self.dir, self.names[i]),
                            "r", create=False)
            self._data[i] = b
        return b

    def read(self, name: str, offset: int, n: int) -> bytes:
        """Bytes ``[offset, offset+n)`` of lost data shard ``name``
        (short only past the shard's recorded EOF)."""
        x = self.names.index(name)
        n = max(0, min(n, self.sizes[x] - offset))
        if n <= 0:
            return b""
        lost_idx = sorted(self.names.index(m) for m in self.lost)
        if x not in lost_idx:
            lost_idx = sorted(lost_idx + [x])
        rows = self.parity_rows[:len(lost_idx)]
        if len(rows) < len(lost_idx):
            raise ScdaError(
                ScdaErrorCode.CORRUPT_CHECKSUM,
                f"{name}: {len(lost_idx)} erasures, "
                f"{len(self.parity_rows)} usable parity rows")
        # Syndromes: S_j = P_j  ^  Σ_{i surviving} c_ji · D_i
        syn: List[np.ndarray] = []
        survivors: List[Tuple[int, np.ndarray]] = []
        for i in range(len(self.names)):
            if i in lost_idx:
                continue
            want = max(0, min(self.sizes[i], offset + n) - offset)
            if want <= 0:
                continue
            buf = np.empty(want, dtype=np.uint8)
            got = self._data_backend(i).preadv(offset, [memoryview(buf)])
            if got < want:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_TRUNCATED,
                    f"{self.names[i]}: EOF at {offset + got}, wanted "
                    f"{offset + want} while reconstructing {name!r}",
                    offset=offset + got)
            survivors.append((i, buf))
        for j, r, data_start in rows:
            acc = np.frombuffer(
                r._backend.pread(data_start + offset, n),
                dtype=np.uint8).copy()
            for i, d in survivors:
                _mul_into(acc, _coeff(i, j), d)
            syn.append(acc)
        if len(lost_idx) == 1:
            j0 = rows[0][0]
            out = syn[0]
            c = _coeff(lost_idx[0], j0)
            if c != 1:
                out = _mul_table(gf_inv(c))[out]
            return out.tobytes()
        # Two erasures x < y: Cramer over the 2×2 GF system.
        ex, ey = lost_idx
        (ja, _, _), (jb, _, _) = rows[0], rows[1]
        a, b = _coeff(ex, ja), _coeff(ey, ja)
        c, d = _coeff(ex, jb), _coeff(ey, jb)
        det = gf_mul(a, d) ^ gf_mul(b, c)
        if det == 0:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"singular code matrix for erasures "
                            f"{ex},{ey}")
        inv_det = gf_inv(det)
        dx = np.zeros(n, dtype=np.uint8)
        _mul_into(dx, gf_mul(d, inv_det), syn[0])
        _mul_into(dx, gf_mul(b, inv_det), syn[1])
        dy = np.zeros(n, dtype=np.uint8)
        _mul_into(dy, gf_mul(c, inv_det), syn[0])
        _mul_into(dy, gf_mul(a, inv_det), syn[1])
        return (dx if x == ex else dy).tobytes()

    def close(self) -> None:
        for b in self._data.values():
            try:
                b.close()
            except ScdaError:
                pass
        self._data = {}
        for _, r, _ in getattr(self, "parity_rows", []):
            try:
                r.close()
            except ScdaError:
                pass
        self.parity_rows = []


class DegradedBackend(FileBackend):
    """A :class:`FileBackend` whose byte source is reconstruction.

    Every FileBackend read path funnels into ``_pread_upto`` /
    ``preadv``; both are overridden to pull bytes out of a
    :class:`SetReconstructor`, so the readahead cache, coalesced
    scatter reads and §3 decode all work unchanged.  ``fd`` stays -1:
    ``prefetch`` and ``advise`` already no-op on fd < 0, and ``close``
    skips the os.close.
    """

    def __init__(self, recon: SetReconstructor, name: str,
                 close_recon: bool = False) -> None:
        self.path = os.path.join(recon.dir, name)
        self.mode = "r"
        self._inj = None
        self.fd = -1
        self._recon = recon
        self._recon_name = name
        self._recon_owned = close_recon
        self._size = recon.shard_size(name)
        import threading
        from repro.core.io_backend import DEFAULT_READAHEAD
        self._readahead = DEFAULT_READAHEAD
        self._cache = b""
        self._cache_off = 0
        self._pf_lock = threading.Lock()
        self._pf = {}
        self._pf_pool = None
        self._wb_lock = threading.Lock()
        self._wb = []
        self._wb_pool = None
        self._wb_error = None
        self._wb_poison = None

    def _pread_upto(self, offset: int, n: int) -> bytes:
        return self._recon.read(self._recon_name, offset, n)

    def preadv(self, offset: int, bufs) -> int:
        got = 0
        for v in bufs:
            v = v if isinstance(v, memoryview) else memoryview(v)
            if not len(v):
                continue
            data = self._recon.read(self._recon_name, offset + got, len(v))
            v[:len(data)] = data
            got += len(data)
            if len(data) < len(v):
                break
        return got

    def size(self) -> int:
        return self._size

    def close(self, sync: bool = False) -> None:
        if self._recon_owned:
            self._recon.close()


def degraded_reader(path: str, doc: Dict[str, Any], name: str,
                    comm=None, quiet: bool = False) -> ScdaReader:
    """An :class:`ScdaReader` over the reconstructed bytes of lost data
    shard ``name`` of the set at ``path`` — the transparent degraded
    restore path.  Raises (FS_OPEN / CORRUPT_CHECKSUM) when the loss
    exceeds the parity budget."""
    recon = SetReconstructor(path, doc, lost=(name,))
    if not quiet:
        warn_degraded(os.path.basename(path), sorted(recon.lost),
                      recon.via)
    backend = DegradedBackend(recon, name, close_recon=True)
    try:
        return ScdaReader(comm, backend.path, backend=backend)
    except BaseException:
        backend.close()
        raise


def degraded_base_reader(base_dir: str, name: str,
                         comm=None) -> Optional[ScdaReader]:
    """Degraded open of a delta-chain base that happens to be a shard of
    a parity-protected set; None when ``name`` is not recoverable this
    way (caller re-raises its original error)."""
    from repro.checkpoint import sharding as _sharding
    hit = _sharding.is_shard_name(name)
    if hit is None:
        return None
    mpath = os.path.join(base_dir, hit[0])
    try:
        doc = _sharding.read_sharded_manifest(mpath)
    except (ScdaError, OSError, ValueError):
        return None
    if not doc.get("parity") \
            or name not in [s.get("file") for s in doc.get("shards", [])]:
        return None
    try:
        return degraded_reader(mpath, doc, name, comm=comm)
    except (ScdaError, OSError):
        return None


# --------------------------------------------------------------------------
# Rebuild + set health (repair / fsck)
# --------------------------------------------------------------------------

def rebuild_shard(path: str, doc: Dict[str, Any], name: str, *,
                  dry_run: bool = False) -> int:
    """Re-materialize lost shard ``name`` of the set at ``path`` in
    place: reconstruct its full byte stream, verify the bytes parse and
    the content id matches the manifest pin, then atomically rename into
    place (dir-fsynced).  Returns the shard's byte size."""
    from repro.checkpoint import pytree_io as pio
    from repro.checkpoint import sharding as _sharding
    recs = {s["file"]: s for s in doc.get("shards", [])}
    if name in recs:
        recon = SetReconstructor(path, doc, lost=(name,))
        try:
            size = recon.shard_size(name)
            backend = DegradedBackend(recon, name)
            with ScdaReader(None, backend.path, backend=backend) as r:
                sdoc = pio._read_header_sections(r)
                _sharding._check_shard_doc(recs[name], sdoc)
            if dry_run:
                return size
            target = os.path.join(recon.dir, name)
            tmp = target + ".rebuild"
            with open(tmp, "wb") as out:
                for off in range(0, size, _STREAM_CHUNK):
                    out.write(recon.read(
                        name, off, min(_STREAM_CHUNK, size - off)))
                out.flush()
                os.fsync(out.fileno())
            replace_file(tmp, target)
            fsync_dir(recon.dir)
            return size
        finally:
            recon.close()
    # A lost *parity* shard recomputes from the (complete) data shards.
    prec = doc.get("parity") or {}
    for j, rec in enumerate(prec.get("files", [])):
        if rec.get("file") != name:
            continue
        missing_data = [s["file"] for s in doc.get("shards", [])
                        if not os.path.exists(
                            os.path.join(os.path.dirname(path),
                                         s["file"]))]
        if missing_data:
            raise ScdaError(
                ScdaErrorCode.FS_OPEN,
                f"cannot recompute parity {name!r}: data shard(s) "
                f"{missing_data} missing — rebuild those first")
        if dry_run:
            return int(rec.get("bytes", 0))
        out = write_parity_files(path, doc.get("shards", []),
                                 int(prec.get("m", 0)),
                                 step=doc.get("step"),
                                 tmp_suffix=".rebuild", in_suffix="",
                                 sync=True)
        d = os.path.dirname(os.path.abspath(path))
        for jj, frec in enumerate(out["files"]):
            src = os.path.join(d, frec["file"])
            if frec["file"] == name:
                if frec["id"] != rec.get("id"):
                    os.remove(src + ".rebuild")
                    raise ScdaError(
                        ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"recomputed parity {name!r} id {frec['id']} != "
                        f"recorded {rec.get('id')} — a data shard was "
                        f"rewritten since the set was saved")
                replace_file(src + ".rebuild", src)
            else:
                os.remove(src + ".rebuild")
        fsync_dir(d)
        return int(rec.get("bytes", 0))
    raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                    f"{name!r} is not a shard of this set")


def set_health(path: str, doc: Optional[Dict[str, Any]] = None) \
        -> Tuple[str, List[str], List[str]]:
    """Erasure-code health of the set at ``path``:
    ``("clean" | "degraded-recoverable" | "unrecoverable",
    lost_data_names, lost_parity_names)``.

    Lost means missing or wrong-sized (data), or missing /
    id-mismatched (parity) — the same cheap classification the
    reconstructor applies before any payload reads.
    """
    from repro.checkpoint import sharding as _sharding
    if doc is None:
        doc = _sharding.read_sharded_manifest(path)
    base = os.path.dirname(os.path.abspath(path))
    lost_data: List[str] = []
    for srec in doc.get("shards", []):
        name = srec.get("file", "")
        spath = os.path.join(base, name)
        try:
            if os.path.getsize(spath) != srec.get("bytes"):
                lost_data.append(name)
        except OSError:
            lost_data.append(name)
    prec = doc.get("parity") or {}
    lost_parity: List[str] = []
    for rec in prec.get("files", []):
        if verify_parity_file(os.path.join(base, rec.get("file", "")),
                              rec):
            lost_parity.append(rec.get("file", ""))
    if not lost_data and not lost_parity:
        return ("clean", [], [])
    usable = len(prec.get("files", [])) - len(lost_parity)
    if len(lost_data) <= usable:
        return ("degraded-recoverable", lost_data, lost_parity)
    return ("unrecoverable", lost_data, lost_parity)
