"""Content-addressed incremental checkpoints — save cost ∝ changed bytes.

A delta checkpoint stores only the leaf chunks whose content changed
since a base checkpoint, and records every unchanged chunk as a by-hash
reference into the base archive.  The moving parts:

* **Digests** (:func:`repro.checkpoint.manifest.chunk_strong_hashes`):
  every leaf's byte stream is chunked deterministically
  (:func:`layout.chunk_sizes`) and each chunk hashed at snapshot time
  with a 128-bit SHA-256 prefix over the *uncompressed* bytes, so a
  chunk's identity survives a compression-setting change.  The strong
  hash alone keys the dedup decision; the manifest's CRC32 column is a
  read-side integrity checksum — computed for stored chunks, inherited
  from the base for unchanged ones — and a CRC32 collision alone can
  never mark a chunk unchanged.
* **Planning** (:func:`plan_refs`): the fresh digest tables are compared
  against the base manifest's.  Unchanged chunks become ``(src, elem)``
  references — fully *flattened* at save time (a chunk the base itself
  borrowed from its own base is referenced at its true home), so a
  chained restore needs only the newest manifest, never a recursive
  walk.  Changed chunks ride the normal pipelined snapshot → deflate →
  pwritev path into a V/zV varray holding just the present subset — the
  archive stays byte-valid scda end to end.
* **Identity** (:func:`repro.checkpoint.manifest.content_id`): each
  referenced base is pinned by a deterministic content id recomputed
  when the base is opened; a base rewritten in place since the delta was
  saved fails loudly (CORRUPT_CHECKSUM) instead of assembling silently
  wrong tensors.  Mode-'a' appends (the journal) do not disturb the id —
  references resolve through the base's own index by user string, never
  by remembered offsets.
* **Resolution** (:class:`ChainResolver` / :func:`restore_chained`):
  restore walks the newest manifest, groups every assembly unit's chunks
  by source archive, and drives one overlapped read pipeline per archive
  (``prefetch_bytes <= 0`` is the serial oracle, as everywhere).  Every
  chunk is CRC32-verified against the manifest on the way in, with the
  exact failing byte offset attached on mismatch.

Tooling on top: :func:`verify_chain` (digest-verify every chunk across
the chain), :func:`squash` (materialize a self-contained archive,
byte-identical to a direct full save of the same state), and
:func:`checkpoint_diff` (logical chain-aware diff).
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import layout, manifest as mf
from repro.core import codec, partition, spec
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.pipeline import ReadItem, run_pipeline
from repro.core.reader import fopen_read

#: Enable incremental saves in :class:`CheckpointManager` by default.
DELTA_ENV = "REPRO_SCDA_DELTA"
#: Maximum chain depth before the manager forces a full save (bounds
#: restore fan-in and lets retention eventually drop old bases).
CHAIN_ENV = "REPRO_SCDA_DELTA_CHAIN"
DEFAULT_CHAIN = 8


def delta_enabled_default() -> bool:
    return os.environ.get(DELTA_ENV, "0") not in ("0", "", "no")


def chain_limit() -> int:
    try:
        return max(1, int(os.environ.get(CHAIN_ENV, DEFAULT_CHAIN)))
    except ValueError:
        return DEFAULT_CHAIN


def base_usable(doc: Dict[str, Any]) -> bool:
    """Can ``doc``'s archive serve as a delta base?  It must carry chunk
    digests for at least one leaf (pre-delta archives hash nothing —
    a delta against them would store every byte for zero benefit)."""
    return any(l.get("chunks") for l in doc.get("leaves", []))


# --------------------------------------------------------------------------
# Save-side planning
# --------------------------------------------------------------------------

def plan_refs(specs: List[mf.LeafSpec], base_doc: Dict[str, Any],
              base_file: str,
              views: Optional[List[Any]] = None) -> Dict[str, Any]:
    """Annotate ``specs`` (which already carry fresh ``chunks`` hash
    tables) with cross-archive chunk references against ``base_doc``.

    The dedup decision is keyed on the 128-bit strong hash alone (plus
    full geometry comparability) — the standard content-addressing
    assumption.  CRC32 is a read-side integrity checksum, never a dedup
    key, so a CRC32 collision alone can never mark a chunk unchanged.
    When ``views`` (per-spec byte views, aligned with ``specs``) are
    given, missing CRC32 tables are completed here: stored chunks are
    checksummed from the bytes in hand, unchanged chunks inherit the
    base's CRC32 (their bytes are identical by hash equality) — the
    incremental save never CRCs the unchanged fraction.

    Mutates each spec in place — ``store="delta"``, ``present`` (chunk
    indices stored in this archive), ``src`` (per chunk: 0 = this
    archive, k ≥ 1 = the k-th entry of the returned ``bases`` list),
    ``elem`` (element index in the source section; for src 0 the
    position within ``present``), and ``sections`` (per referenced base,
    the leaf's section user string there) — and returns the manifest's
    top-level delta table ``{"bases": [...], "depth": k}``.

    References are flattened: a chunk the base itself borrowed resolves
    to its true home archive, so the table is transitive-closure-free
    and restore never recurses.
    """
    bases: List[Dict[str, str]] = []
    interned: Dict[Tuple[str, str], int] = {}

    def intern(file: str, cid: str) -> int:
        key = (file, cid)
        if key not in interned:
            bases.append({"file": file, "id": cid})
            interned[key] = len(bases)
        return interned[key]

    base_by_name = {bl["name"]: (bi, bl)
                    for bi, bl in enumerate(base_doc.get("leaves", []))}
    base_id = mf.content_id(base_doc)
    base_bases = (base_doc.get("delta") or {}).get("bases", [])

    for si, spec_ in enumerate(specs):
        table = spec_["chunks"]
        cb = int(table["bytes"])
        hashes = table["hash"]
        sizes = layout.chunk_sizes(spec_["nbytes"], cb)
        view = views[si] if views is not None else None
        crcs: Optional[List[int]] = \
            None if table.get("crc32") is not None else []
        if crcs is not None and view is None:
            raise ValueError(
                f"leaf {spec_['name']}: chunk table has no crc32 and no "
                f"byte view was supplied to complete it")
        src: List[int] = []
        elem: List[int] = []
        present: List[int] = []
        sections: Dict[str, str] = {}
        hit = base_by_name.get(spec_["name"])
        btable = hit[1].get("chunks") if hit else None
        comparable = (
            btable is not None
            and hit[1].get("shape") == spec_["shape"]
            and hit[1].get("dtype") == spec_["dtype"]
            and hit[1].get("nbytes") == spec_["nbytes"]
            and int(btable.get("bytes", -1)) == cb
            and len(btable.get("hash", ())) == len(hashes))
        for c in range(len(hashes)):
            unchanged = comparable and btable["hash"][c] == hashes[c]
            if not unchanged:
                src.append(0)
                elem.append(len(present))
                present.append(c)
                if crcs is not None:
                    pos = c * cb
                    crcs.append(zlib.crc32(
                        view[pos:pos + sizes[c]]) & 0xFFFFFFFF)
                continue
            if crcs is not None:
                crcs.append(btable["crc32"][c])
            bi, bl = hit
            if bl.get("store") == "delta" and bl["src"][c] != 0:
                bb = base_bases[bl["src"][c] - 1]
                sid = intern(bb["file"], bb["id"])
                user = bl["sections"][str(bl["src"][c])]
            elif bl.get("store") == "delta":
                sid = intern(base_file, base_id)
                user = mf.leaf_user_string(bi).decode("ascii")
            else:
                sid = intern(base_file, base_id)
                user = mf.leaf_user_string(bi).decode("ascii")
            belem = bl["elem"][c] if bl.get("store") == "delta" else c
            src.append(sid)
            elem.append(belem)
            sections[str(sid)] = user
        if crcs is not None:
            table["crc32"] = crcs
        spec_["store"] = "delta"
        spec_["present"] = present
        spec_["src"] = src
        spec_["elem"] = elem
        if sections:
            spec_["sections"] = sections
    depth = int((base_doc.get("delta") or {}).get("depth", 0)) + 1
    return {"bases": bases, "depth": depth}


# --------------------------------------------------------------------------
# Restore-side resolution
# --------------------------------------------------------------------------

class _SrcSection:
    """One leaf's section in one source archive, parsed for chunk reads."""

    __slots__ = ("entry", "kind", "esizes", "usizes", "csizes", "offs",
                 "path")

    def __init__(self, r, sec: int) -> None:
        e = r.index().entries[sec]
        r.verify_index_entry(sec, e)
        self.entry = e
        self.kind = e.kind
        self.path = r.path
        self.esizes = self.usizes = self.csizes = self.offs = None
        if e.kind == "V":
            self.esizes = r._parse_entries(e.entries_start, 0, e.N, b"E")
            self.offs = partition.offsets(self.esizes)
        elif e.kind == "zV":
            self.usizes = r._parse_entries(e.entries_start, 0, e.N, b"U")
            self.csizes = r._parse_entries(e.v_entries_start, 0, e.N, b"E")
            self.offs = partition.offsets(self.csizes)
        elif e.kind != "A":
            raise ScdaError(
                ScdaErrorCode.CORRUPT_SECTION_TYPE,
                f"{r.path}: section {sec} has kind {e.kind}, cannot hold "
                f"leaf chunks", offset=e.start)

    def chunk_read(self, elemi: int, usize: int, chunk_bytes: int,
                   leaf: str) -> Tuple[Tuple[int, int], bool, Optional[int]]:
        """Locate one chunk: ``((offset, length), inflate, expected)``.

        ``elemi`` is the element index the manifest recorded for the
        chunk in this section (for A sections, the chunk index itself);
        a source whose element table disagrees with the manifest's chunk
        geometry is corrupt — CORRUPT_CHECKSUM at the failing entry.
        """
        e = self.entry
        if self.kind == "A":
            off = elemi * chunk_bytes
            if off + usize > e.N * e.E:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_CHECKSUM,
                    f"leaf {leaf}: chunk element {elemi} extends past the "
                    f"source section in {self.path}",
                    offset=e.data_start + off)
            return (e.data_start + off, usize), False, None
        if elemi >= e.N:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_CHECKSUM,
                f"leaf {leaf}: chunk element {elemi} out of range "
                f"(section holds {e.N}) in {self.path}",
                offset=e.entries_start)
        entry_off = e.entries_start + elemi * spec.COUNT_ENTRY_BYTES
        if self.kind == "V":
            if self.esizes[elemi] != usize:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_CHECKSUM,
                    f"leaf {leaf}: source element {elemi} holds "
                    f"{self.esizes[elemi]} bytes, chunk geometry needs "
                    f"{usize} ({self.path})", offset=entry_off)
            return ((e.data_start + self.offs[elemi], usize), False, None)
        if self.usizes[elemi] != usize:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_CHECKSUM,
                f"leaf {leaf}: source element {elemi} inflates to "
                f"{self.usizes[elemi]} bytes, chunk geometry needs "
                f"{usize} ({self.path})", offset=entry_off)
        return ((e.v_data_start + self.offs[elemi], self.csizes[elemi]),
                True, usize)


class ChainResolver:
    """Lazy, content-id-verified access to a delta chain's archives.

    Source 0 is the primary reader (already open); sources k ≥ 1 open
    the manifest's k-th base on first use, recompute its content id from
    its own manifest, and refuse a mismatch — the stale-base guard.
    Base readers are rank-local (plain positioned reads on a shared
    file), so chained restores stay partition-independent.
    """

    def __init__(self, r, doc: Dict[str, Any]) -> None:
        self.primary = r
        self.doc = doc
        self.base_dir = os.path.dirname(r.path)
        self.bases = (doc.get("delta") or {}).get("bases", [])
        self._readers: Dict[int, Any] = {0: r}
        self._sections: Dict[Tuple[int, bytes], _SrcSection] = {}

    def base_file(self, sid: int) -> str:
        if sid == 0:
            return os.path.basename(self.primary.path)
        return self.bases[sid - 1]["file"]

    def reader(self, sid: int):
        r = self._readers.get(sid)
        if r is not None:
            return r
        from repro.checkpoint import pytree_io
        if not 1 <= sid <= len(self.bases):
            raise ScdaError(
                ScdaErrorCode.CORRUPT_ENCODING,
                f"chunk reference to base #{sid}, manifest lists "
                f"{len(self.bases)}")
        b = self.bases[sid - 1]
        path = os.path.join(self.base_dir, b["file"])
        try:
            r = self._open_base(pytree_io, b, path)
        except ScdaError as e:
            # A lost/corrupt base that is a shard of a parity-protected
            # set reconstructs transparently (degraded chain read).
            r = None
            if e.code == ScdaErrorCode.FS_OPEN or e.group == 1:
                from repro.checkpoint import redundancy as _red
                r = _red.degraded_base_reader(self.base_dir, b["file"])
            if r is None:
                raise
            try:
                bdoc = pytree_io._read_header_sections(r)
                got = mf.content_id(bdoc)
                if got != b.get("id"):
                    raise ScdaError(
                        ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"delta base {b['file']}: reconstructed content "
                        f"id {got} != recorded {b.get('id')}", offset=0)
            except BaseException:
                r.close()
                raise
        self._readers[sid] = r
        return r

    def _open_base(self, pytree_io, b: Dict[str, Any], path: str):
        try:
            r = fopen_read(None, path)
        except ScdaError as e:
            raise ScdaError(
                e.code, f"delta base {b['file']} unreadable: {e}",
                offset=e.offset) from e
        try:
            bdoc = pytree_io._read_header_sections(r)
            got = mf.content_id(bdoc)
            if got != b.get("id"):
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_CHECKSUM,
                    f"delta base {b['file']}: content id {got} != recorded "
                    f"{b.get('id')} — the base archive was rewritten since "
                    f"this delta was saved", offset=0)
            pytree_io._adopt_sidecar(r)
        except BaseException:
            r.close()
            raise
        return r

    def section(self, sid: int, user: bytes) -> _SrcSection:
        key = (sid, user)
        s = self._sections.get(key)
        if s is None:
            from repro.checkpoint import pytree_io
            r = self.reader(sid)
            # Tolerant resolution: a torn post-commit append on a base
            # archive must not demote every delta stacked on top of it.
            sec = pytree_io._resolve_index(r).find(user)
            if sec < 0:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_ENCODING,
                    f"{self.base_file(sid)}: no section with user string "
                    f"{user!r} (delta chunk source)")
            s = _SrcSection(r, sec)
            self._sections[key] = s
        return s

    def close(self) -> None:
        for sid, r in list(self._readers.items()):
            if sid != 0:
                try:
                    r.close()
                except ScdaError:
                    pass
        self._readers = {0: self.primary}
        self._sections.clear()


def _scatter_subset(runs, chunks: Dict[int, Any], chunk_bytes: int,
                    arr: np.ndarray) -> None:
    """Scatter a chunk *subset* into a unit buffer — the per-source half
    of :func:`pytree_io._scatter_chunks_np`, tolerating absent chunks
    (they arrive from a different source archive's pipeline)."""
    for goff, loff, n in runs:
        for c, data in chunks.items():
            cstart = c * chunk_bytes
            lo = max(goff, cstart)
            hi = min(goff + n, cstart + len(data))
            if lo >= hi:
                continue
            arr[loff + (lo - goff):loff + (hi - goff)] = \
                np.frombuffer(data, np.uint8, hi - lo, lo - cstart)


def restore_chained(r, doc: Dict[str, Any], wanted, prefetch_bytes: int, *,
                    resolver: Optional[ChainResolver] = None,
                    strong: bool = False) -> Dict[str, Any]:
    """Restore ``wanted`` leaves of a delta checkpoint across its chain.

    ``wanted``: ``(name, manifest_index, spec, target)`` tuples as in
    :func:`pytree_io._restore_pipelined`.  Every assembly unit's chunks
    are grouped by source archive and each archive is drained through
    one overlapped read pipeline (serial when ``prefetch_bytes <= 0``).
    Every chunk is CRC32-verified against the manifest digest table —
    corruption anywhere in the chain surfaces as CORRUPT_CHECKSUM with
    the absolute failing byte offset in the archive that holds the
    chunk.  ``strong`` additionally checks the 128-bit SHA-256 (the
    ``verify --chain`` mode).
    """
    from repro.checkpoint import pytree_io as pio
    own = resolver is None
    resolver = resolver or ChainResolver(r, doc)
    try:
        return _restore_chained(pio, resolver, wanted, prefetch_bytes,
                                strong)
    finally:
        if own:
            resolver.close()


def _restore_chained(pio, resolver: ChainResolver, wanted,
                     prefetch_bytes: int, strong: bool) -> Dict[str, Any]:
    leaves: List[Dict[str, Any]] = []
    items_by_src: Dict[int, List[ReadItem]] = {}
    for leaf_pos, (name, i, spec_, target) in enumerate(wanted):
        table = spec_.get("chunks")
        if spec_.get("store") != "delta" or table is None:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_ENCODING,
                f"leaf {name}: delta manifest entry lacks chunk references")
        leaf = pio._leaf_layout(name, spec_, target)
        cb = int(table["bytes"])
        usizes = layout.chunk_sizes(spec_["nbytes"], cb)
        src, elem = spec_["src"], spec_["elem"]
        if not (len(src) == len(elem) == len(usizes)
                == len(table["crc32"]) == len(table["hash"])):
            raise ScdaError(
                ScdaErrorCode.CORRUPT_ENCODING,
                f"leaf {name}: chunk reference tables disagree with the "
                f"leaf geometry")
        for ui, unit in enumerate(leaf["units"]):
            needed = layout.chunks_for_runs(unit.runs, cb)
            by_sid: Dict[int, List[int]] = {}
            for c in needed:
                by_sid.setdefault(src[c], []).append(c)
            for sid, cs in sorted(by_sid.items()):
                user = (mf.leaf_user_string(i) if sid == 0
                        else spec_["sections"][str(sid)].encode("ascii"))
                sect = resolver.section(sid, user)
                plan = []
                inflate = False
                for c in cs:
                    ext, inf, _exp = sect.chunk_read(elem[c], usizes[c],
                                                     cb, name)
                    inflate = inf
                    plan.append((c, ext))
                plan.sort(key=lambda p: p[1][0])
                order = [c for c, _ in plan]
                extents = [ext for _, ext in plan]
                items_by_src.setdefault(sid, []).append(ReadItem(
                    (leaf_pos, ui, order, sid, extents), extents,
                    inflate=inflate,
                    expected_sizes=([usizes[c] for c in order]
                                    if inflate else None)))
                leaf["pending"] += 1
        leaves.append(leaf)

    values: Dict[str, Any] = {}
    for leaf in leaves:  # zero-byte / fully-absent leaves
        if leaf["pending"] == 0:
            values[leaf["name"]] = pio._finalize_leaf(leaf)
    for sid in sorted(items_by_src):
        rr = resolver.reader(sid)
        items = sorted(items_by_src[sid], key=lambda it: it.start())
        try:
            _drain_source(pio, resolver, leaves, values, rr, items,
                          prefetch_bytes, strong)
        except ScdaError as e:
            if e.offset is not None:
                raise
            # the codec pool raises without a position — re-find the
            # failing stream serially so the error names the exact byte
            raise _localize_failure(rr, items, e)
    return values


def _localize_failure(rr, items: List[ReadItem], err: ScdaError) \
        -> ScdaError:
    """Pin an offset-less inflate failure to the first bad stream —
    corruption reports must carry the exact byte, not just 'a deflate
    stream somewhere in this archive broke'."""
    for it in items:
        if not it.inflate:
            continue
        for j, (off, n) in enumerate(it.extents):
            try:
                raw = codec.decompress(rr._backend.pread(off, n))
            except ScdaError:
                return err.at(off)
            if it.expected_sizes is not None \
                    and len(raw) != it.expected_sizes[j]:
                return err.at(off)
    return err


def _drain_source(pio, resolver: ChainResolver, leaves, values, rr,
                  items: List[ReadItem], prefetch_bytes: int,
                  strong: bool) -> None:
    for key, res in run_pipeline(rr._backend, items, prefetch_bytes):
        leaf_pos, ui, order, sid_, extents = key
        leaf = leaves[leaf_pos]
        table = leaf["spec"]["chunks"]
        cb = int(table["bytes"])
        chunks: Dict[int, Any] = {}
        for c, payload, ext in zip(order, res, extents):
            if (zlib.crc32(payload) & 0xFFFFFFFF) != table["crc32"][c]:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_CHECKSUM,
                    f"leaf {leaf['name']}: chunk {c} from "
                    f"{resolver.base_file(sid_)} fails its recorded "
                    f"CRC32", offset=ext[0])
            if strong:
                got = mf.chunk_hash(bytes(payload))
                if got != table["hash"][c]:
                    raise ScdaError(
                        ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"leaf {leaf['name']}: chunk {c} from "
                        f"{resolver.base_file(sid_)} fails its recorded "
                        f"content hash", offset=ext[0])
            chunks[c] = payload
        unit = leaf["units"][ui]
        _scatter_subset(unit.runs, chunks, cb, unit.arr)
        leaf["pending"] -= 1
        if leaf["pending"] == 0:
            values[leaf["name"]] = pio._finalize_leaf(leaf)


# --------------------------------------------------------------------------
# Chain tooling: verify / squash / diff
# --------------------------------------------------------------------------

def verify_chain(path: str) -> List[str]:
    """Digest-verify every chunk of a checkpoint across its delta chain.

    For delta archives each leaf is resolved through the chain with both
    the CRC32 and the strong hash checked per chunk; full archives with
    recorded digest tables are re-hashed leaf by leaf.  Returns problem
    strings (empty = clean); collection is per leaf, so one bad leaf
    does not mask the rest.

    A sharded-set manifest verifies the whole set: manifest-vs-disk
    consistency (existence / size / pinned content id per shard) first,
    then every shard archive's own chain.
    """
    from repro.checkpoint import pytree_io as pio
    with fopen_read(None, path) as r:
        doc = pio._read_header_sections(r)
        if doc.get("format") == mf.SHARDED_FORMAT:
            sharded = doc
        else:
            return _verify_chain_flat(pio, r, doc)
    from repro.checkpoint import sharding as _sharding
    problems = _sharding.verify_set(path)
    base_dir = os.path.dirname(os.path.abspath(path))
    for k, srec in enumerate(sharded.get("shards", [])):
        spath = os.path.join(base_dir, srec.get("file", ""))
        if not os.path.exists(spath):
            continue  # verify_set already reported the missing file
        try:
            sub = verify_chain(spath)
        except (ScdaError, OSError, ValueError) as e:
            # A torn shard fails before its leaves can be walked; report
            # it as this shard's problem and keep checking the others.
            sub = [str(e)]
        for p in sub:
            problems.append(f"shard #{k} {srec.get('file')!r}: {p}")
    return problems


def _verify_chain_flat(pio, r, doc: Dict[str, Any]) -> List[str]:
    problems: List[str] = []
    pio._adopt_sidecar(r)
    resolver = ChainResolver(r, doc)
    try:
        for i, spec_ in enumerate(doc["leaves"]):
            name = spec_["name"]
            table = spec_.get("chunks")
            if table is None:
                if doc.get("delta"):
                    problems.append(
                        f"leaf {name}: no chunk digests recorded")
                continue
            try:
                if doc.get("delta"):
                    restore_chained(r, doc, [(name, i, spec_, None)], 0,
                                    resolver=resolver, strong=True)
                else:
                    values = pio._restore_pipelined(
                        r, [(name, i, spec_, None)], 0)
                    host = np.asarray(values[name])
                    view = pio._byte_view(host)
                    sizes = layout.chunk_sizes(spec_["nbytes"],
                                               int(table["bytes"]))
                    crcs, hashes = mf.chunk_digests(view, sizes)
                    for c, (crc, h) in enumerate(zip(crcs, hashes)):
                        if (crc != table["crc32"][c]
                                or h != table["hash"][c]):
                            problems.append(
                                f"leaf {name}: chunk {c} fails its "
                                f"recorded digest")
            except ScdaError as e:
                problems.append(f"leaf {name}: {e}")
    finally:
        resolver.close()
    return problems


def squash(src_path: str, dst_path: str, *, comm=None,
           write_window: Optional[int] = None,
           prefetch_bytes: Optional[int] = None) -> Dict[str, Any]:
    """Materialize a self-contained full checkpoint from a delta chain.

    Leaves are resolved through the chain (overlapped, digest-checked)
    and rewritten in manifest order with fresh digest tables — the
    output is byte-identical to a direct full ``save(...,
    record_hashes=True)`` of the same state, so a squashed archive can
    seed a new chain.  Works on full archives too (a digest-recording
    rewrite), and on sharded sets — the squash of a sharded chain is one
    self-contained single-file archive of the whole logical state.
    Returns the new manifest document.
    """
    from repro.checkpoint import pytree_io as pio
    pf = pio._effective_prefetch(prefetch_bytes)
    with fopen_read(None, src_path) as r:
        doc = pio._read_header_sections(r)
        if doc.get("format") == mf.SHARDED_FORMAT:
            values = None  # resolved below, once the manifest is closed
        else:
            pio._adopt_sidecar(r)
            wanted = [(s["name"], i, s, None)
                      for i, s in enumerate(doc["leaves"])]
            if doc.get("delta"):
                values = restore_chained(r, doc, wanted, pf)
            elif wanted:
                values = pio._restore_pipelined(r, wanted, pf)
            else:
                values = {}
    if values is None:
        from repro.checkpoint import sharding as _sharding
        doc = _sharding.combined_document(src_path)
        values, _ = _sharding.restore_flat(src_path,
                                           prefetch_bytes=prefetch_bytes)
    compressed = any(bool(s.get("compressed")) for s in doc["leaves"])
    chunk_bytes = pio.DEFAULT_CHUNK_BYTES
    for s in doc["leaves"]:
        if s.get("chunks"):
            chunk_bytes = int(s["chunks"]["bytes"])
            break
        if s.get("chunk_bytes"):
            chunk_bytes = int(s["chunk_bytes"])
            break
    arrays: List[Any] = []
    leaves: List[mf.LeafSpec] = []
    for s in doc["leaves"]:
        arrays.append(values[s["name"]])
        leaves.append(mf.LeafSpec.make(
            s["name"], tuple(s["shape"]), mf.dtype_from_name(s["dtype"]),
            compressed, chunk_bytes))
    return pio._write_checkpoint(
        dst_path, comm=comm, step=doc.get("step"), leaves=leaves,
        arrays=arrays, aux=doc.get("aux", {}), compressed=compressed,
        chunk_bytes=chunk_bytes, write_window=write_window,
        record_hashes=True)


def checkpoint_diff(path_a: str, path_b: str) -> List[str]:
    """Logical diff of two checkpoints, chain-aware.

    Compares step, aux, and leaf geometry from the manifests; leaf
    payloads compare by digest table when both sides recorded one under
    the same chunking (no payload reads at all), and by resolved bytes
    otherwise — so a delta archive diffs against a full one without ever
    materializing the unchanged fraction.  Sharded sets diff by their
    combined logical document, so a sharded save diffs cleanly against a
    single-file one (and against a set with a different shard count).
    Returns difference lines (empty = logically identical).
    """
    from repro.checkpoint import pytree_io as pio

    def _logical(path: str) -> Dict[str, Any]:
        d = pio.read_manifest(path)
        if d.get("format") == mf.SHARDED_FORMAT:
            from repro.checkpoint import sharding as _sharding
            return _sharding.combined_document(path, doc=d)
        return d

    da, db = _logical(path_a), _logical(path_b)
    lines: List[str] = []
    if da.get("step") != db.get("step"):
        lines.append(f"step: {da.get('step')} != {db.get('step')}")
    aux_a, aux_b = da.get("aux", {}), db.get("aux", {})
    for k in sorted(set(aux_a) | set(aux_b)):
        if (k in aux_a) != (k in aux_b) or aux_a.get(k) != aux_b.get(k):
            lines.append(f"aux {k}: {aux_a.get(k, '<absent>')!r} != "
                         f"{aux_b.get(k, '<absent>')!r}")
    la = {l["name"]: l for l in da["leaves"]}
    lb = {l["name"]: l for l in db["leaves"]}
    for n in sorted(set(la) | set(lb)):
        if n not in lb:
            lines.append(f"leaf {n}: only in {os.path.basename(path_a)}")
            continue
        if n not in la:
            lines.append(f"leaf {n}: only in {os.path.basename(path_b)}")
            continue
        a, b = la[n], lb[n]
        if a["shape"] != b["shape"] or a["dtype"] != b["dtype"]:
            lines.append(
                f"leaf {n}: geometry {a['shape']}/{a['dtype']} != "
                f"{b['shape']}/{b['dtype']}")
            continue
        ta, tb = a.get("chunks"), b.get("chunks")
        if ta and tb and int(ta["bytes"]) == int(tb["bytes"]):
            if ta["hash"] != tb["hash"] or ta["crc32"] != tb["crc32"]:
                diff = [c for c in range(len(ta["hash"]))
                        if ta["hash"][c] != tb["hash"][c]
                        or ta["crc32"][c] != tb["crc32"][c]]
                lines.append(f"leaf {n}: {len(diff)}/{len(ta['hash'])} "
                             f"chunks differ (first: chunk "
                             f"{diff[0] if diff else '?'})")
            continue
        va = np.asarray(pio.restore_leaf(path_a, n))
        vb = np.asarray(pio.restore_leaf(path_b, n))
        if bytes(pio._byte_view(va)) != bytes(pio._byte_view(vb)):
            lines.append(f"leaf {n}: payload bytes differ")
    return lines
