"""Checkpoint lifecycle management for long-running training jobs.

Fault-tolerance properties (the paper's motivating use case, §A.6: "file
errors should never crash the simulation"):

  * **Async**: the only synchronous work is the device→host snapshot;
    serialization + disk I/O run on a background thread (straggler-safe —
    checkpoint I/O never sits on the training critical path).
  * **Atomic**: writes go to ``<name>.tmp`` and are fsync'd before an
    atomic rename; a crash mid-write never leaves a visible partial
    checkpoint, and ``latest_step`` only ever sees complete files.
  * **Non-fatal**: any ScdaError during a save is recorded and surfaced on
    the *next* call (or ``wait()``), never raised into the training loop
    mid-step unless the caller asks.
  * **Elastic**: ``restore_latest(like=...)`` restores under any mesh; the
    file does not know or care how many hosts wrote it.
  * **Retention**: keep the newest ``keep`` checkpoints (always ≥ 1), so a
    corrupted latest file can fall back to an older one.
  * **Incremental**: with ``delta=True`` (or ``REPRO_SCDA_DELTA=1``) a
    save stores only the leaf chunks whose content changed since the
    newest committed checkpoint; unchanged chunks become by-hash
    references into earlier archives.  Retention is chain-aware — every
    base a retained delta still references (transitively) is protected,
    so dropping old steps never strands a chain.
  * **Journaled**: :meth:`CheckpointManager.journal` streams training
    telemetry (loss/lr/eval scalars) into the newest committed checkpoint
    file via mode-'a' appends; buffered records are flushed right after
    every commit (flush-on-commit ordering), so the archive that holds
    the state also holds the metrics that led to it.
"""
from __future__ import annotations

import os
import re
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import delta as _delta
from repro.checkpoint import pytree_io
from repro.checkpoint import redundancy as _red
from repro.checkpoint import sharding as _sharding
from repro.checkpoint import manifest as _mf
from repro.core import ScdaError
from repro.core import trace as _trace
from repro.core.comm import Communicator, SerialComm
from repro.core.errors import ScdaErrorCode
from repro.core.index import SIDECAR_SUFFIX, ScdaIndex
from repro.core.io_backend import replace_durable

_CKPT_RE = re.compile(r"^step_(\d{10})\.scda$")

#: Advisory writer lock: O_EXCL-created in the checkpoint directory so
#: two managers on one directory refuse instead of interleaving commits.
LOCK_NAME = ".scda-lock"

#: A foreign-host lock older than this is presumed dead (we cannot
#: signal-probe across hosts); same-host locks are probed by pid.
LOCK_TTL_SECONDS = 3600.0


def _ckpt_name(step: int) -> str:
    return f"step_{step:010d}.scda"


def snapshot_to_host(tree):
    """Synchronous device→host copy preserving shape/dtype (per shard).

    For single-process jax.Arrays the result is plain numpy (canonical
    layout); the background writer then never touches device state, so
    training can overwrite donated buffers immediately.
    """
    def _snap(x):
        if isinstance(x, jax.Array):
            return np.asarray(x)
        return x
    return jax.tree_util.tree_map(_snap, tree)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 compressed: bool = False,
                 comm: Optional[Communicator] = None,
                 chunk_bytes: int = pytree_io.DEFAULT_CHUNK_BYTES,
                 index_sidecar: bool = True,
                 delta: Optional[bool] = None,
                 delta_chain: Optional[int] = None,
                 shards: Optional[int] = None,
                 parity: Optional[int] = None) -> None:
        self.directory = directory
        self.keep = max(1, keep)
        self.compressed = compressed
        self.comm = comm or SerialComm()
        self.chunk_bytes = chunk_bytes
        self.index_sidecar = index_sidecar
        # Multi-file sharded saves: N independent archives + a manifest
        # file per checkpoint (None defers to REPRO_SCDA_SHARDS; 0 =
        # classic single-file saves).  See repro.checkpoint.sharding.
        self.shards = (_sharding.shards_default()
                       if shards is None else max(0, int(shards)))
        # Erasure coding: m parity shards per set (None defers to
        # REPRO_SCDA_PARITY).  Parity without sharding has nothing to
        # code over, so it collapses to 0 for flat saves.
        self.parity = (_red.parity_default()
                       if parity is None else max(0, int(parity)))
        if not self.shards:
            self.parity = 0
        _red.check_geometry(self.shards, self.parity)
        # Incremental (delta) saves: None defers to REPRO_SCDA_DELTA; the
        # chain depth cap (REPRO_SCDA_DELTA_CHAIN) forces a periodic full
        # save so restore fan-in stays bounded and retention can
        # eventually drop old bases.
        self.delta = (_delta.delta_enabled_default()
                      if delta is None else bool(delta))
        self.delta_chain = (_delta.chain_limit()
                            if delta_chain is None else max(1, delta_chain))
        self._last_doc: Optional[Tuple[Dict[str, Any], str]] = None
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._journal = None  # lazy ScdaJournal (see journal())
        self._crash_before_commit = False  # test hook: simulated node death
        self._lock_path = os.path.join(directory, LOCK_NAME)
        self._lock_owned = False
        if self.comm.rank == 0:
            os.makedirs(directory, exist_ok=True)
            self._acquire_lock()
        self.comm.barrier()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Join any in-flight save and release the writer lock."""
        try:
            self.wait()
        finally:
            if self._lock_owned and self.comm.rank == 0:
                try:
                    os.remove(self._lock_path)
                except OSError:
                    pass
                self._lock_owned = False

    # -- advisory writer lock ------------------------------------------------
    def _acquire_lock(self) -> None:
        """O_EXCL lockfile (pid/host/timestamp) in the checkpoint dir.

        A live holder refuses loudly; a stale holder (dead pid on this
        host, or a foreign-host lock past LOCK_TTL_SECONDS) is taken
        over with a loud warning.  A lock held by THIS process is
        silently shared — managers and tooling routinely reopen the
        same directory in-process, and the advisory target is two
        *jobs*, not two objects.
        """
        import json
        import socket
        import time
        me = {"pid": os.getpid(), "host": socket.gethostname(),
              "time": time.time()}
        for _ in range(16):  # bounded takeover races
            try:
                fd = os.open(self._lock_path,
                             os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644)
            except FileExistsError:
                pass
            else:
                with os.fdopen(fd, "w") as f:
                    f.write(json.dumps(me))
                self._lock_owned = True
                return
            try:
                with open(self._lock_path, "r") as f:
                    cur = json.loads(f.read() or "{}")
            except (OSError, ValueError):
                cur = {}
            if not isinstance(cur, dict):
                cur = {}
            if cur.get("host") == me["host"] \
                    and cur.get("pid") == me["pid"]:
                return  # same process — shared advisory lock
            stale = False
            if not cur:
                stale = True  # unreadable/empty lock: crashed mid-write
            elif cur.get("host") == me["host"] \
                    and isinstance(cur.get("pid"), int):
                try:
                    os.kill(cur["pid"], 0)
                except OSError:
                    stale = True  # holder process is gone
            else:
                try:
                    age = time.time() - float(cur.get("time", 0))
                except (TypeError, ValueError):
                    age = LOCK_TTL_SECONDS + 1
                stale = age > LOCK_TTL_SECONDS
            if not stale:
                raise ScdaError(
                    ScdaErrorCode.FS_OPEN,
                    f"checkpoint directory {self.directory!r} is locked "
                    f"by pid {cur.get('pid')} on {cur.get('host')!r} "
                    f"(since {cur.get('time')}); remove "
                    f"{self._lock_path!r} if that writer is gone")
            _trace.warn(
                f"repro: TAKING OVER stale checkpoint lock "
                f"{self._lock_path!r} (holder pid {cur.get('pid')} on "
                f"{cur.get('host')!r} presumed dead)",
                key=("lock-takeover", self._lock_path))
            try:
                os.remove(self._lock_path)
            except OSError:
                pass  # lost a takeover race; retry the O_EXCL create
        raise ScdaError(
            ScdaErrorCode.FS_OPEN,
            f"could not acquire checkpoint lock {self._lock_path!r}")

    # -- inventory -----------------------------------------------------------
    def all_steps(self) -> List[int]:
        try:
            names = os.listdir(self.directory)
        except OSError:
            return []
        steps = [int(m.group(1)) for n in names
                 if (m := _CKPT_RE.match(n))]
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def path_for(self, step: int) -> str:
        return os.path.join(self.directory, _ckpt_name(step))

    # -- journaling ----------------------------------------------------------
    def journal(self):
        """The run's telemetry journal (:class:`repro.journal.ScdaJournal`).

        ``journal().log(step, scalars)`` buffers records; they are
        appended to the newest *committed* checkpoint file — immediately
        when the auto-flush threshold trips, and in any case right after
        every commit, re-targeted at the fresh file (flush-on-commit:
        telemetry logged before ``save(step)`` is on disk inside
        ``step``'s archive once that save commits).  Before the first
        commit records simply buffer.  Rank 0 only, like the sidecars —
        metrics are replicated, the file needs them once, so every other
        rank gets an inert journal (log is a no-op there) and replicated
        training code may log unconditionally.  Note retention applies:
        journal history lives in the retained checkpoint files.
        """
        if self._journal is None:
            from repro.journal import ScdaJournal
            latest = self.latest_step()
            self._journal = ScdaJournal(
                self.path_for(latest) if latest is not None else None,
                enabled=self.comm.rank == 0)
        return self._journal

    # -- saving ----------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False,
             aux_extra: Optional[Dict[str, Any]] = None,
             delta: Optional[bool] = None) -> None:
        """Snapshot now; serialize + write in the background.

        ``delta=True`` saves incrementally against the newest committed
        checkpoint: unchanged chunks become by-hash references, save cost
        is proportional to the changed bytes (``None`` defers to the
        manager's / ``REPRO_SCDA_DELTA``'s default).  Falls back to a
        full save when no usable base exists or the chain depth cap is
        reached.

        Raises any error from the *previous* async save (so failures are
        observed, but off the hot path).
        """
        self.wait()  # one in-flight save at a time; surfaces prior errors
        host_tree = snapshot_to_host(tree)
        use_delta = self.delta if delta is None else bool(delta)

        def _write() -> None:
            try:
                self._write_and_commit(step, host_tree, aux_extra,
                                       use_delta)
            except BaseException as e:  # noqa: BLE001 - stored, not raised
                self._error = e

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=_write, daemon=True,
                                            name=f"ckpt-save-{step}")
            self._thread.start()

    def _delta_base(self, step: int) \
            -> Optional[Tuple[Dict[str, Any], str]]:
        """The ``(manifest_doc, file_name)`` the next delta should
        reference, or ``None`` to force a full save.

        ``None`` when: no prior checkpoint exists, the newest one carries
        no chunk digests (pre-delta archive), re-saving ``step`` would
        make the archive reference itself, or the chain depth cap is
        reached (periodic full save keeps restore fan-in bounded and
        lets retention eventually drop old bases).
        """
        target = _ckpt_name(step)
        cand: Optional[Tuple[Dict[str, Any], str]] = None
        if self._last_doc is not None and self._last_doc[1] != target:
            cand = self._last_doc
        else:
            for s in reversed(self.all_steps()):
                name = _ckpt_name(s)
                if name == target:
                    continue  # never self-reference on a same-step re-save
                try:
                    doc = pytree_io.read_manifest(self.path_for(s))
                    if doc.get("format") == _mf.SHARDED_FORMAT:
                        # A sharded base needs its per-shard docs (the
                        # actual digest tables) — content-id-verified,
                        # so a tampered set falls back to a full save.
                        doc = _sharding.load_set(self.path_for(s))
                except (ScdaError, OSError, ValueError):
                    continue  # unreadable base: fall further back
                cand = (doc, name)
                break
        if cand is None or not _sharding.base_usable_any(cand[0]):
            return None
        if _sharding.chain_depth(cand[0]) + 1 > self.delta_chain:
            return None
        return cand

    def _write_and_commit(self, step: int, host_tree,
                          aux_extra: Optional[Dict[str, Any]],
                          use_delta: bool = False) -> None:
        final = self.path_for(step)
        tmp = final + ".tmp"
        with _trace.span("plan", "ckpt", step=step, delta=use_delta,
                         shards=self.shards, parity=self.parity):
            base = self._delta_base(step) if use_delta else None
        try:
            if self.shards:
                # Sharded save: every file (shards + manifest) is written
                # as <name>.tmp while the manifest records final names;
                # commit_sharded renames shards first, manifest last —
                # the manifest rename is the commit point.
                doc = _sharding.save_sharded(
                    final, host_tree, shards=self.shards, comm=self.comm,
                    step=step, compressed=self.compressed,
                    chunk_bytes=self.chunk_bytes, aux_extra=aux_extra,
                    record_hashes=use_delta or self.delta,
                    delta_base=base, parity=self.parity,
                    tmp_suffix=".tmp")
            else:
                doc = pytree_io.save(tmp, host_tree, comm=self.comm,
                                     step=step,
                                     compressed=self.compressed,
                                     chunk_bytes=self.chunk_bytes,
                                     aux_extra=aux_extra,
                                     record_hashes=use_delta or self.delta,
                                     delta_base=base, shards=0)
        except BaseException:
            # A failed save must not leave its half-written tmp around
            # until the next retention sweep: remove it now (best-effort
            # — the atomic-rename invariant already keeps it invisible)
            # and surface the original error unchanged.
            if self.comm.rank == 0:
                stale = (_sharding.set_paths(final, self.shards, ".tmp",
                                             parity=self.parity)
                         if self.shards else [tmp])
                for p in stale:
                    try:
                        os.remove(p)
                    except OSError:
                        pass
            raise
        if self._crash_before_commit:
            raise RuntimeError("injected crash before commit")
        self.comm.barrier()
        if self.comm.rank == 0:
            with _trace.span("commit", "ckpt", path=final, step=step):
                if self.shards:
                    _sharding.commit_sharded(final, doc, ".tmp")
                    committed = [os.path.join(self.directory, s["file"])
                                 for s in doc["shards"]]
                    committed += [os.path.join(self.directory, p["file"])
                                  for p in (doc.get("parity") or {})
                                  .get("files", [])]
                    committed.append(final)
                else:
                    # Atomic commit: rename + parent-dir fsync.  Without
                    # the directory fsync a power cut can roll the rename
                    # back and lose the commit entirely.
                    replace_durable(tmp, final)
                    committed = [final]
                if self.index_sidecar:
                    # The .scdax sidecars make restore_leaf / lazy
                    # restores seek without a scan.  Best-effort: the
                    # checkpoint is already committed, and readers fall
                    # back to a fresh header scan when a sidecar is
                    # missing or stale.
                    ScdaIndex.write_sidecars(committed)
            c = _trace.collector()
            if c is not None:
                # Metrics sink: counter deltas since the last commit ride
                # into the checkpoint's own journal, so the archive that
                # holds the state also records the I/O it cost.
                rec = c.commit_record()
                if rec:
                    self.journal().log(step, {"trace": rec})
            if self._journal is not None:
                # Flush-on-commit: buffered telemetry follows the newest
                # checkpoint into its file (and refreshes the sidecar it
                # just grew past, atomically).  Best-effort like the
                # sidecar — a failed flush keeps the records buffered
                # for the next commit, never un-commits the checkpoint.
                self._journal.retarget(final)
                try:
                    self._journal.flush()
                except (ScdaError, OSError):
                    pass
            with _trace.span("retention", "ckpt", keep=self.keep):
                self._apply_retention()
        # Cache the exact doc a re-read of the fresh archive would parse —
        # the next delta save references it without touching the disk.
        self._last_doc = (doc, _ckpt_name(step))
        self.comm.barrier()

    def _shard_files(self, name: str) -> List[str]:
        """Shard + parity file names of checkpoint ``name`` (empty for
        flat archives or anything unreadable).  Parity rides along so
        retention treats the whole erasure-coded set as one atomic
        unit — a dropped checkpoint takes its parity with it, a kept
        one keeps its parity restorable."""
        try:
            doc = pytree_io.read_manifest(
                os.path.join(self.directory, name))
        except (ScdaError, OSError, ValueError):
            return []
        if doc.get("format") != _mf.SHARDED_FORMAT:
            return []
        return [s.get("file") for s in doc.get("shards", [])
                if s.get("file")] \
            + [p.get("file")
               for p in (doc.get("parity") or {}).get("files", [])
               if p.get("file")]

    def _referenced_files(self, kept_steps: List[int]) -> set:
        """Transitive closure of delta-base files the kept checkpoints
        still reference — retention must not delete them, or every
        surviving delta becomes unrestorable.  Sharded manifests are
        traversed through their shard archives (whose docs hold the
        actual base references); the bases a sharded delta records are
        shard *files*, so protection lands on those names and the
        retention sweep keeps their whole set."""
        protected: set = set()
        queue = [_ckpt_name(s) for s in kept_steps]
        seen = set(queue)
        while queue:
            name = queue.pop()
            try:
                doc = pytree_io.read_manifest(
                    os.path.join(self.directory, name))
            except (ScdaError, OSError, ValueError):
                continue  # unreadable: nothing to protect through it
            if doc.get("format") == _mf.SHARDED_FORMAT:
                for s in doc.get("shards", []):
                    f = s.get("file")
                    if f and f not in seen:
                        seen.add(f)
                        queue.append(f)  # traverse, don't protect
                continue
            for b in (doc.get("delta") or {}).get("bases", []):
                f = b.get("file")
                if f and f not in seen:
                    seen.add(f)
                    protected.add(f)
                    queue.append(f)
        return protected

    def _apply_retention(self) -> None:
        steps = self.all_steps()
        protected = self._referenced_files(steps[-self.keep:])
        for s in steps[:-self.keep]:
            files = [_ckpt_name(s)] + self._shard_files(_ckpt_name(s))
            if any(f in protected for f in files):
                continue  # an alive delta chain still needs this base
            for f in files:
                p = os.path.join(self.directory, f)
                for path in (p, p + SIDECAR_SUFFIX):
                    try:
                        os.remove(path)
                    except OSError:
                        pass  # retention is best-effort
        # sweep stale tmp files from crashed attempts, orphaned sidecars,
        # and shard files whose manifest is gone (a crashed sharded
        # commit renames shards before the manifest)
        keep_names = set(protected)
        for s in self.all_steps():
            n = _ckpt_name(s)
            keep_names.add(n)
            keep_names.update(self._shard_files(n))
        for n in os.listdir(self.directory):
            stale = (n.endswith(".scda.tmp") or n.endswith(".scdax.tmp")
                     or (n.endswith(".scda" + SIDECAR_SUFFIX)
                         and n[:-len(SIDECAR_SUFFIX)] not in keep_names)
                     or (_sharding.is_shard_name(n) is not None
                         and n not in keep_names)
                     or (_red.is_parity_name(n) is not None
                         and n not in keep_names))
            if stale:
                try:
                    os.remove(os.path.join(self.directory, n))
                except OSError:
                    pass

    def wait(self) -> None:
        """Join any in-flight save and surface its error, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restoring ---------------------------------------------------------------
    def restore(self, step: int, like=None) -> Tuple[Any, Optional[int]]:
        return pytree_io.restore(self.path_for(step), like, comm=self.comm)

    def restore_leaf(self, step: int, name: str, like=None):
        """Lazily load one tensor of checkpoint ``step`` (index seek)."""
        return pytree_io.restore_leaf(self.path_for(step), name, like,
                                      comm=self.comm)

    def restore_latest(self, like=None) -> Tuple[Any, Optional[int]]:
        """Restore the newest complete checkpoint; fall back on corruption.

        Node-failure recovery: a half-written or corrupted newest file
        (e.g. the job died during a commit on another file system) must not
        brick the restart — older retained checkpoints are tried in order.
        """
        steps = self.all_steps()
        last_err: Optional[BaseException] = None
        for step in reversed(steps):
            try:
                return self.restore(step, like)
            except ScdaError as e:
                last_err = e
                continue
        if last_err is not None:
            raise last_err
        return None, None

    def restore_or_init(self, init_fn, like=None):
        """The standard restart entry point: resume if possible, else init.

        Returns ``(tree, step)`` where step is -1 for a fresh start.
        """
        steps = self.all_steps()
        if steps:
            tree, step = self.restore_latest(like)
            if tree is not None:
                return tree, (step if step is not None else steps[-1])
        return init_fn(), -1
