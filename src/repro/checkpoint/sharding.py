"""Multi-file sharded checkpoints — one manifest, N independent archives.

Fleet-scale checkpoints outgrow single files and single filesystems; the
scda answer is to keep the format untouched and lift the paper's §2
partition-independence invariant one level up.  A sharded save splits
the leaf set deterministically across ``N`` ordinary scda checkpoint
archives (each written through the existing overlapped save engine, each
individually byte-identical to a serial ``save`` of its leaf subset) and
records the set in one small **manifest file** that is itself a valid
scda file — exactly like the ``.scdax`` sidecar:

    F  header (user string "repro ckpt-shards")
    I  "scda-ckpt status"       — same human-readable step line
    B  "scda-shards manifest"   — JSON: shard files + content ids +
                                  byte sizes, leaf→shard placement, aux

The per-shard digest tables live where they always did — in each shard's
own manifest (chunk CRC32 + SHA-256 tables when recorded) — and the set
manifest pins every shard by its deterministic
:func:`repro.checkpoint.manifest.content_id`, so a shard rewritten in
place since the set was saved refuses loudly (CORRUPT_CHECKSUM) instead
of assembling silently wrong tensors.  Because shards are plain
checkpoints, delta chains compose: a sharded delta save pairs shard *k*
against the base's shard *k* (or against a single-file base), and every
shard archive resolves through the ordinary
:class:`repro.checkpoint.delta.ChainResolver`.

Readers may use any process count regardless of the writer's shard
count: ``restore``/``restore_leaf``/``restore(like=)`` resolve the
manifest transparently (see the delegation hooks in
:mod:`repro.checkpoint.pytree_io`) and open each needed shard
collectively in a deterministic order.

Knobs: ``CheckpointManager(shards=N)`` or ``REPRO_SCDA_SHARDS=N``
(0 = classic single-file saves).

Module-level imports stay jax-free so ``scdatool``'s cheap metadata
paths (ls/fsck summaries) can inspect sharded sets without pulling jax;
:mod:`repro.checkpoint.pytree_io` is imported lazily inside the
restore/save bodies.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint import manifest as mf
from repro.core import trace as _trace
from repro.core.comm import Communicator, SerialComm
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.io_backend import fsync_dir, replace_file
from repro.core.reader import fopen_read
from repro.core.writer import fopen_write

#: ``REPRO_SCDA_SHARDS``: default shard count for saves (0 = single file).
SHARDS_ENV = "REPRO_SCDA_SHARDS"

SHARDED_FORMAT = mf.SHARDED_FORMAT

#: ``<stem>-s<k>of<n>.scda`` — what a shard file is named.  The step
#: pattern the manager scans for (``step_NNNNNNNNNN.scda``) can never
#: match a shard name, so shard files are invisible to ``all_steps``.
_SHARD_RE = re.compile(r"^(?P<stem>.+)-s(?P<k>\d+)of(?P<n>\d+)\.scda$")


def shards_default() -> int:
    """Resolve the ``REPRO_SCDA_SHARDS`` knob (0 / unset = single file)."""
    try:
        return max(0, int(os.environ.get(SHARDS_ENV, "0")))
    except ValueError:
        return 0


def shard_file(path: str, k: int, n: int) -> str:
    """Path of shard ``k`` of ``n`` for the manifest at ``path``."""
    stem = path[:-len(".scda")] if path.endswith(".scda") else path
    width = max(2, len(str(n - 1)), len(str(n)))
    return f"{stem}-s{k:0{width}d}of{n:0{width}d}.scda"


def is_shard_name(name: str) -> Optional[Tuple[str, int, int]]:
    """``(manifest_name, k, n)`` if ``name`` looks like a shard file,
    else None — the retention sweep uses this to spot orphaned shards."""
    m = _SHARD_RE.match(name)
    if not m:
        return None
    return (m.group("stem") + ".scda", int(m.group("k")), int(m.group("n")))


def assign_shards(sizes: List[int], n: int) -> List[int]:
    """Deterministic greedy balance: walk leaves in manifest order,
    placing each on the least-loaded shard (ties → lowest index).

    Walking in manifest order (not sorted by size) keeps a leaf's shard
    stable under small tree changes, which is what lets sharded delta
    saves keep matching leaves against the same base shard.
    """
    loads = [0] * n
    out: List[int] = []
    for s in sizes:
        k = min(range(n), key=lambda i: (loads[i], i))
        out.append(k)
        loads[k] += max(1, int(s))  # zero-byte leaves still take a slot
    return out


# --------------------------------------------------------------------------
# Saving
# --------------------------------------------------------------------------

def _shard_delta_base(base: Optional[Tuple[Dict[str, Any], str]],
                      k: int) -> Optional[Tuple[Dict[str, Any], str]]:
    """The per-shard ``(doc, file)`` delta base derived from a set-level
    base: shard ``k`` pairs with the base's shard ``k`` (sharded base) or
    with the whole archive (single-file base).  Leaves that moved shards
    simply miss their name in the paired base doc and are stored fully —
    correctness never depends on the pairing, only the dedup hit rate.
    """
    from repro.checkpoint import delta as _delta
    if base is None:
        return None
    bdoc, bname = base
    if bdoc.get("format") == SHARDED_FORMAT:
        sdocs = bdoc.get("shard_docs")
        if not sdocs or k >= len(sdocs):
            return None
        if not _delta.base_usable(sdocs[k]):
            return None
        return (sdocs[k], bdoc["shards"][k]["file"])
    if not _delta.base_usable(bdoc):
        return None
    return (bdoc, bname)


def save_sharded(path: str, tree, *, shards: int,
                 comm: Optional[Communicator] = None,
                 step: Optional[int] = None, compressed: bool = False,
                 chunk_bytes: Optional[int] = None,
                 aux_extra: Optional[Dict[str, Any]] = None,
                 write_window: Optional[int] = None,
                 record_hashes: bool = False,
                 delta_base: Optional[Tuple[Dict[str, Any], str]] = None,
                 parity: int = 0,
                 tmp_suffix: str = "") -> Dict[str, Any]:
    """Write ``tree`` as ``shards`` independent scda archives plus a
    manifest file at ``path``.

    Each shard goes through :func:`pytree_io._write_checkpoint` with its
    leaf subset in global manifest order — the identical code path a
    serial ``save`` of that subset takes, so per-shard serial
    equivalence is structural, not re-proven.  ``tmp_suffix`` is
    appended to every file actually written (the manager's atomic
    commit renames them; the manifest records the *final* names).

    Returns the sharded manifest document augmented with ``shard_docs``
    (the in-memory per-shard manifest docs, for delta-base caching).
    """
    from repro.checkpoint import pytree_io as pio
    comm = comm or SerialComm()
    n = max(1, int(shards))
    if chunk_bytes is None:
        chunk_bytes = pio.DEFAULT_CHUNK_BYTES
    named, _ = pio.flatten_named(tree)
    leaves: List[mf.LeafSpec] = []
    arrays: List[Any] = []
    aux: Dict[str, Any] = dict(aux_extra or {})
    for name, value in named:
        if pio._is_array(value):
            import numpy as np
            leaves.append(mf.LeafSpec.make(
                name, tuple(np.shape(value)), value.dtype,
                compressed, chunk_bytes))
            arrays.append(value)
        else:
            aux[name] = pio._encode_aux(value)

    placement = assign_shards([l["nbytes"] for l in leaves], n)
    _trace.event("shard_placement", "ckpt", shards=n,
                 leaves=len(leaves), parity=parity)
    shard_recs: List[Dict[str, Any]] = []
    shard_docs: List[Dict[str, Any]] = []
    placed: List[Dict[str, Any]] = []
    for k in range(n):
        idxs = [i for i, p in enumerate(placement) if p == k]
        for j, i in enumerate(idxs):
            placed.append({"name": leaves[i]["name"], "shard": k,
                           "index": j, "nbytes": leaves[i]["nbytes"],
                           "_order": i})
        sfile = shard_file(path, k, n)
        sdoc = pio._write_checkpoint(
            sfile + tmp_suffix, comm=comm, step=step,
            leaves=[leaves[i] for i in idxs],
            arrays=[arrays[i] for i in idxs], aux={},
            compressed=compressed, chunk_bytes=chunk_bytes,
            write_window=write_window, record_hashes=record_hashes,
            delta_base=_shard_delta_base(delta_base, k))
        shard_docs.append(sdoc)
        shard_recs.append({
            "file": os.path.basename(sfile),
            "id": mf.content_id(sdoc),
            "bytes": int(os.path.getsize(sfile + tmp_suffix)),
            "leaves": len(idxs),
        })
    placed.sort(key=lambda e: e["_order"])
    for e in placed:
        del e["_order"]
    doc = {
        "format": mf.SHARDED_FORMAT,
        "version": mf.SHARDED_VERSION,
        "step": step,
        "aux": aux,
        "shards": shard_recs,
        "leaves": placed,
    }
    if parity > 0 and comm.rank == 0:
        from repro.checkpoint import redundancy as _red
        doc["parity"] = _red.write_parity_files(
            path, shard_recs, parity, step=step, tmp_suffix=tmp_suffix,
            sync=True)
    if parity > 0 and comm.size > 1:
        doc["parity"] = comm.bcast(doc.get("parity"), 0)
    # The manifest file: valid scda, tiny, written last (commit point
    # when tmp_suffix is empty — a crash mid-save leaves shards without
    # a manifest, which the retention sweep collects as orphans).
    with fopen_write(comm, path + tmp_suffix,
                     user_string=mf.SHARDS_FILE_USER_STRING,
                     sync=True) as f:
        f.write_inline(mf.STATUS_USER_STRING, mf.status_inline(step),
                       root=0)
        f.write_block(
            mf.SHARDS_MANIFEST_USER_STRING,
            mf.build_sharded(doc) if comm.rank == 0 else None,
            E=None, root=0)
    out = dict(doc)
    out["shard_docs"] = shard_docs
    return out


def set_paths(path: str, shards: int, tmp_suffix: str = "",
              parity: int = 0) -> List[str]:
    """Every file a ``save_sharded(path, shards=N, parity=m,
    tmp_suffix=...)`` writes — shards, then parity, manifest last
    (commit order)."""
    from repro.checkpoint import redundancy as _red
    n = max(1, int(shards))
    return [shard_file(path, k, n) + tmp_suffix for k in range(n)] \
        + _red.set_parity_paths(path, parity, tmp_suffix) \
        + [path + tmp_suffix]


def commit_sharded(path: str, doc: Dict[str, Any],
                   tmp_suffix: str) -> None:
    """Atomically rename a sharded tmp set into place: shards (and
    parity) first, manifest last — the manifest rename is the commit
    point, and until it lands no reader can resolve the half-renamed
    set."""
    n = len(doc["shards"])
    d = os.path.dirname(os.path.abspath(path))
    with _trace.span("commit", "ckpt", path=path, shards=n):
        for k in range(n):
            sfile = shard_file(path, k, n)
            replace_file(sfile + tmp_suffix, sfile)
        for rec in (doc.get("parity") or {}).get("files", []):
            pfile = os.path.join(d, rec["file"])
            replace_file(pfile + tmp_suffix, pfile)
        # Shard renames must be durable BEFORE the manifest rename: the
        # manifest is the commit point, so once it lands every shard
        # entry it names has to survive the same power cut.
        fsync_dir(d)
        replace_file(path + tmp_suffix, path)
        fsync_dir(d)


# --------------------------------------------------------------------------
# Opening / verifying a set
# --------------------------------------------------------------------------

def read_sharded_manifest(path: str,
                          comm: Optional[Communicator] = None) \
        -> Dict[str, Any]:
    """The sharded manifest document of ``path`` (no shard opens)."""
    with fopen_read(comm, path) as r:
        hdr = r.read_section_header()
        if hdr.type != "I" or hdr.user_string != mf.STATUS_USER_STRING:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            "not a sharded checkpoint: missing status "
                            "inline")
        step = mf.parse_status_inline(r.read_inline_data())
        hdr = r.read_section_header()
        if hdr.type != "B" \
                or hdr.user_string != mf.SHARDS_MANIFEST_USER_STRING:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            "not a sharded checkpoint: missing shards "
                            "manifest block")
        doc = mf.parse_sharded(r.read_block_data())
        if doc.get("step") is None:
            doc["step"] = step
        return doc


def _shard_rec(doc: Dict[str, Any], k: int) -> Dict[str, Any]:
    shards = doc.get("shards", [])
    if not 0 <= k < len(shards):
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"leaf placement names shard {k}, manifest lists "
                        f"{len(shards)}")
    return shards[k]


def _open_shard(spath: str, srec: Dict[str, Any],
                comm: Optional[Communicator]):
    """Collectively open one shard, naming the absent file on failure."""
    try:
        return fopen_read(comm, spath)
    except ScdaError as e:
        if e.code == ScdaErrorCode.FS_OPEN \
                and not os.path.exists(spath):
            raise ScdaError(
                ScdaErrorCode.FS_OPEN,
                f"missing shard file {srec.get('file')!r}: {e}") from e
        raise
    except FileNotFoundError as e:
        raise ScdaError(
            ScdaErrorCode.FS_OPEN,
            f"missing shard file {srec.get('file')!r}: {e}") from e


def _check_shard_doc(srec: Dict[str, Any], sdoc: Dict[str, Any]) -> None:
    got = mf.content_id(sdoc)
    if got != srec.get("id"):
        raise ScdaError(
            ScdaErrorCode.CORRUPT_CHECKSUM,
            f"shard {srec.get('file')!r}: content id {got} != recorded "
            f"{srec.get('id')} — the shard was rewritten since the set "
            f"was saved")


def load_set(path: str, *, comm: Optional[Communicator] = None,
             verify: bool = True) -> Dict[str, Any]:
    """The sharded manifest doc with every shard's own manifest attached
    as ``shard_docs`` (content-id-verified unless ``verify=False``)."""
    from repro.checkpoint import pytree_io as pio
    doc = read_sharded_manifest(path, comm)
    base = os.path.dirname(path)
    sdocs: List[Dict[str, Any]] = []
    for srec in doc.get("shards", []):
        spath = os.path.join(base, srec.get("file", ""))
        with _open_shard(spath, srec, comm) as r:
            sdoc = pio._read_header_sections(r)
        if verify:
            _check_shard_doc(srec, sdoc)
        sdocs.append(sdoc)
    doc["shard_docs"] = sdocs
    return doc


def verify_set(path: str) -> List[str]:
    """Manifest-vs-disk consistency of a sharded set; returns problem
    strings (empty = consistent).  Checks existence (naming the absent
    file), recorded byte size, and the pinned content id of every shard —
    the cheap metadata pass ``scdatool verify``/``fsck`` runs before any
    payload validation."""
    from repro.checkpoint import pytree_io as pio
    problems: List[str] = []
    try:
        doc = read_sharded_manifest(path)
    except (ScdaError, OSError, ValueError) as e:
        return [f"manifest unreadable: {e}"]
    base = os.path.dirname(os.path.abspath(path))
    for k, srec in enumerate(doc.get("shards", [])):
        name = srec.get("file", "")
        spath = os.path.join(base, name)
        if not os.path.exists(spath):
            problems.append(f"shard #{k} {name!r}: missing shard file")
            continue
        size = os.path.getsize(spath)
        if size != srec.get("bytes"):
            problems.append(
                f"shard #{k} {name!r}: {size} bytes on disk, manifest "
                f"recorded {srec.get('bytes')}")
        try:
            with fopen_read(None, spath) as r:
                sdoc = pio._read_header_sections(r)
            _check_shard_doc(srec, sdoc)
        except (ScdaError, OSError, ValueError) as e:
            problems.append(f"shard #{k} {name!r}: {e}")
    if doc.get("parity"):
        from repro.checkpoint import redundancy as _red
        for j, rec in enumerate(doc["parity"].get("files", [])):
            name = rec.get("file", "")
            for p in _red.verify_parity_file(
                    os.path.join(base, name), rec):
                problems.append(f"parity #{j} {name!r}: {p}")
    return problems


def chain_depth(doc: Dict[str, Any]) -> int:
    """Delta-chain depth of a checkpoint doc, sharded or flat (the
    manager's chain-cap check; a sharded doc needs ``shard_docs``)."""
    if doc.get("format") == SHARDED_FORMAT:
        return max((int((sd.get("delta") or {}).get("depth", 0))
                    for sd in doc.get("shard_docs", [])), default=0)
    return int((doc.get("delta") or {}).get("depth", 0))


def base_usable_any(doc: Dict[str, Any]) -> bool:
    """Can ``doc`` (sharded or flat) serve as the next delta's base?"""
    from repro.checkpoint import delta as _delta
    if doc.get("format") == SHARDED_FORMAT:
        return any(_delta.base_usable(sd)
                   for sd in doc.get("shard_docs", []))
    return _delta.base_usable(doc)


# --------------------------------------------------------------------------
# Restoring
# --------------------------------------------------------------------------

def _restore_from_open_shard(r, srec: Dict[str, Any], wanted,
                             pf: int, adopt: bool = True) \
        -> Dict[str, Any]:
    """Restore ``wanted`` — ``(name, shard_leaf_index, target)`` tuples —
    from one OPEN shard reader, content-id-verified against the
    manifest.  ``adopt=False`` skips sidecar adoption (degraded mode:
    the on-disk sidecar describes whatever replaced the lost file, not
    the reconstructed bytes)."""
    from repro.checkpoint import pytree_io as pio
    sdoc = pio._read_header_sections(r)
    _check_shard_doc(srec, sdoc)
    tuples = []
    for name, j, target in wanted:
        if j >= len(sdoc["leaves"]) \
                or sdoc["leaves"][j]["name"] != name:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_ENCODING,
                f"shard {srec.get('file')!r}: manifest places leaf "
                f"{name!r} at index {j}, the shard disagrees")
        tuples.append((name, j, sdoc["leaves"][j], target))
    if adopt:
        pio._adopt_sidecar(r)
    if sdoc.get("delta"):
        from repro.checkpoint import delta as _delta
        return _delta.restore_chained(r, sdoc, tuples, pf)
    if pf > 0:
        return pio._restore_pipelined(r, tuples, pf)
    values: Dict[str, Any] = {}
    for name, j, spec_, target in tuples:
        hdr = r.open_section(mf.leaf_user_string(j))
        pio._check_leaf_header(hdr, spec_)
        values[name] = (pio._read_leaf_full(r, hdr, spec_)
                        if target is None else
                        pio._read_leaf_to_target(r, hdr, spec_,
                                                 target))
    return values


def _degraded_eligible(e: ScdaError) -> bool:
    """Failures the erasure code can route around: a missing file, or
    corruption of the shard's bytes (rewritten file, torn tail, chunk
    CRC / decode failure).  Usage errors (group 3) never degrade."""
    return e.code == ScdaErrorCode.FS_OPEN or e.group == 1


def _restore_from_shard(spath: str, srec: Dict[str, Any], wanted,
                        comm: Optional[Communicator], pf: int,
                        set_ctx: Optional[Tuple[str, Dict[str, Any]]]
                        = None, verify: bool = False) -> Dict[str, Any]:
    """Restore ``wanted`` from one shard archive; when the shard is
    lost or corrupt and the set carries parity (``set_ctx`` =
    ``(manifest_path, doc)``), fall back transparently to a degraded
    read over the surviving shards + parity.  ``verify`` CRC-checks the
    shard against its checksummed sidecar first (skipped on the
    degraded path: the on-disk sidecar describes the lost file, while
    the reconstructed bytes are re-proven by the content-id pin)."""
    try:
        if verify:
            from repro.checkpoint import pytree_io as pio
            pio._verify_archive(spath)
        with _open_shard(spath, srec, comm) as r:
            return _restore_from_open_shard(r, srec, wanted, pf)
    except ScdaError as e:
        if set_ctx is None or not _degraded_eligible(e) \
                or not set_ctx[1].get("parity"):
            raise
        from repro.checkpoint import redundancy as _red
        mpath, doc = set_ctx
        r = _red.degraded_reader(mpath, doc, srec["file"], comm=comm)
        try:
            # pf=0: the serial oracle path — reconstruction already
            # batches survivor reads per range, background prefetch on
            # top would only reorder them.
            return _restore_from_open_shard(r, srec, wanted, 0,
                                            adopt=False)
        finally:
            r.close()


def _by_shard(entries) -> Dict[int, List[Tuple[str, int, Any]]]:
    """Group ``(placement_entry, target)`` pairs by shard, each group in
    within-shard index order — one deterministic collective open per
    shard, every rank visiting the same shards in the same order."""
    groups: Dict[int, List[Tuple[str, int, Any]]] = {}
    for entry, target in entries:
        groups.setdefault(int(entry["shard"]), []).append(
            (entry["name"], int(entry["index"]), target))
    for g in groups.values():
        g.sort(key=lambda w: w[1])
    return groups


def restore_sharded(path: str, doc: Dict[str, Any], like=None, *,
                    comm: Optional[Communicator] = None,
                    prefetch_bytes: Optional[int] = None,
                    verify: bool = False):
    """Restore a sharded checkpoint (the ``pytree_io.restore``
    delegation target).  Semantics mirror the flat restore exactly —
    ``like=None`` rebuilds a nested numpy dict, a ``like`` tree restores
    lazily onto its shardings — with shards opened in deterministic
    order so any reader process count works against any shard count."""
    from repro.checkpoint import pytree_io as pio
    comm = comm or SerialComm()
    pf = pio._effective_prefetch(prefetch_bytes)
    step = doc.get("step")
    aux = doc.get("aux", {})
    base = os.path.dirname(path)
    placed = {e["name"]: e for e in doc.get("leaves", [])}

    if like is None:
        groups = _by_shard([(e, None) for e in doc.get("leaves", [])])
        out: Dict[str, Any] = {}
        for k in sorted(groups):
            srec = _shard_rec(doc, k)
            out.update(_restore_from_shard(
                os.path.join(base, srec.get("file", "")), srec,
                groups[k], comm, pf, set_ctx=(path, doc),
                verify=verify))
        for name, value in aux.items():
            out[name] = value
        return pio._unflatten_names(out), step

    import jax
    named, treedef = pio.flatten_named(like)
    targets = {n: v for n, v in named}
    missing = [n for n in targets if n not in placed and n not in aux]
    if missing:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"leaves missing from checkpoint: {missing[:5]}"
                        f"{'…' if len(missing) > 5 else ''}")
    groups = _by_shard([(placed[n], targets[n])
                        for n in targets if n in placed])
    values: Dict[str, Any] = {}
    for k in sorted(groups):
        srec = _shard_rec(doc, k)
        values.update(_restore_from_shard(
            os.path.join(base, srec.get("file", "")), srec,
            groups[k], comm, pf, set_ctx=(path, doc), verify=verify))
    for name in targets:
        if name in aux:
            values[name] = aux[name]
    leaves_out = [values[n] for n, _ in named]
    return jax.tree_util.tree_unflatten(treedef, leaves_out), step


def restore_leaf_sharded(path: str, doc: Dict[str, Any], name: str,
                         like=None, *,
                         comm: Optional[Communicator] = None,
                         prefetch_bytes: Optional[int] = None,
                         verify: bool = False):
    """Load ONE leaf of a sharded checkpoint: resolve its shard from the
    manifest, open that shard only (the lazy-restore workload, now also
    lazy across *files*)."""
    from repro.checkpoint import pytree_io as pio
    comm = comm or SerialComm()
    pf = pio._effective_prefetch(prefetch_bytes)
    placed = {e["name"]: e for e in doc.get("leaves", [])}
    if name in placed:
        entry = placed[name]
        srec = _shard_rec(doc, int(entry["shard"]))
        return _restore_from_shard(
            os.path.join(os.path.dirname(path), srec.get("file", "")),
            srec, [(name, int(entry["index"]), like)], comm, pf,
            set_ctx=(path, doc), verify=verify)[name]
    if name in doc.get("aux", {}):
        return doc["aux"][name]
    raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                    f"leaf {name!r} not in checkpoint")


def restore_flat(path: str, doc: Optional[Dict[str, Any]] = None, *,
                 prefetch_bytes: Optional[int] = None) \
        -> Tuple[Dict[str, Any], Optional[int]]:
    """Every array leaf of a sharded set as a flat ``{name: ndarray}``
    dict in global manifest order — the tooling entry (``squash``,
    ``diff`` payload fallbacks) that wants values without tree
    structure."""
    from repro.checkpoint import pytree_io as pio
    if doc is None:
        doc = read_sharded_manifest(path)
    pf = pio._effective_prefetch(prefetch_bytes)
    base = os.path.dirname(path)
    groups = _by_shard([(e, None) for e in doc.get("leaves", [])])
    values: Dict[str, Any] = {}
    for k in sorted(groups):
        srec = _shard_rec(doc, k)
        values.update(_restore_from_shard(
            os.path.join(base, srec.get("file", "")), srec,
            groups[k], None, pf, set_ctx=(path, doc)))
    return values, doc.get("step")


def combined_document(path: str, *,
                      doc: Optional[Dict[str, Any]] = None) \
        -> Dict[str, Any]:
    """A flat-checkpoint-shaped view of a sharded set: full leaf specs
    (with digest tables, when recorded) assembled in global manifest
    order — what chain-aware tooling (``diff``) compares against."""
    from repro.checkpoint import pytree_io as pio  # noqa: F401
    if doc is None or "shard_docs" not in doc:
        doc = load_set(path)
    leaves: List[Dict[str, Any]] = []
    for entry in doc.get("leaves", []):
        sdoc = doc["shard_docs"][int(entry["shard"])]
        leaves.append(sdoc["leaves"][int(entry["index"])])
    return {"format": "repro-scda-checkpoint",
            "step": doc.get("step"), "aux": doc.get("aux", {}),
            "leaves": leaves, "sharded": True}


def summarize(path: str) -> Dict[str, Any]:
    """Cheap ls-able summary of a sharded set (manifest reads only)."""
    doc = read_sharded_manifest(path)
    base = os.path.dirname(os.path.abspath(path))
    shards = []
    for srec in doc.get("shards", []):
        name = srec.get("file", "")
        shards.append({
            "file": name,
            "id": srec.get("id"),
            "bytes": srec.get("bytes"),
            "leaves": srec.get("leaves"),
            "present": os.path.exists(os.path.join(base, name)),
        })
    out = {"format": mf.SHARDED_FORMAT,
           "version": doc.get("version", mf.SHARDED_VERSION),
           "step": doc.get("step"), "shards": shards,
           "leaves": len(doc.get("leaves", [])),
           "aux": len(doc.get("aux", {}))}
    prec = doc.get("parity")
    if prec:
        out["parity"] = [{
            "file": rec.get("file"),
            "id": rec.get("id"),
            "bytes": rec.get("bytes"),
            "present": os.path.exists(
                os.path.join(base, rec.get("file", ""))),
        } for rec in prec.get("files", [])]
        out["parity_code"] = prec.get("code")
    return out
