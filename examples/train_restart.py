"""End-to-end driver: train an LM with checkpoint/restart + node failure.

Phase 1  trains a reduced qwen3-family model, checkpointing every K steps,
         then "the node dies" (injected failure mid-run).
Phase 2  reboots the job — same entry point — which restores the latest
         scda checkpoint and finishes the run.  Loss continues from where
         it left off (bit-identical state: the synthetic data pipeline is a
         pure function of the step counter).

On CPU this runs a ~1M-param model for 60 steps; pass --full for the ~100M
configuration (sized for a real accelerator).

Run:  PYTHONPATH=src python examples/train_restart.py [--full]
"""
import argparse
import dataclasses
import logging
import tempfile

from repro.configs import get_config, smoke
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train

logging.basicConfig(level=logging.INFO,
                    format="%(name)s: %(message)s")


def model_config(full: bool):
    base = smoke(get_config("qwen3-1.7b"))
    if not full:
        return base
    # ~100M-param member of the same family
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=12, d_model=768, vocab=32_000,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, a few hundred steps")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    cfg = model_config(args.full)
    steps = args.steps or (300 if args.full else 60)
    ckpt_dir = tempfile.mkdtemp(prefix="repro-train-")
    loop = TrainLoopConfig(total_steps=steps, ckpt_every=max(5, steps // 6),
                           ckpt_dir=ckpt_dir, log_every=max(1, steps // 12))
    die_at = steps // 2
    seq, gb = (512, 32) if args.full else (64, 8)

    print(f"=== phase 1: train to step {die_at}, then the node dies")
    try:
        train(cfg, loop, AdamWConfig(total_steps=steps),
              seq_len=seq, global_batch=gb,
              hooks={"should_die": lambda s: s == die_at})
    except SystemExit as e:
        print(f"    {e}")

    print("=== phase 2: reboot — restore latest checkpoint, finish the run")
    out = train(cfg, loop, AdamWConfig(total_steps=steps),
                seq_len=seq, global_batch=gb)
    assert out["start_step"] >= 0, "restart did not restore a checkpoint"
    print(f"resumed from step {out['start_step']}; "
          f"final loss {out['losses'][-1]:.4f}")
    first, last = out["losses"][0], out["losses"][-1]
    print(f"loss {first:.4f} → {last:.4f} "
          f"({'improving' if last < first else 'flat'})")
    print(f"checkpoints kept: {out['manager'].all_steps()}")


if __name__ == "__main__":
    main()
