"""Quickstart: the scda format in five minutes.

Writes a file with every section type (inline / block / fixed array /
variable array, raw + compressed), proves serial-equivalence by rewriting
the same data under a 3-rank partition, then reads it back under a
different partition and inspects the file with a dumb byte-level scanner.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.core import (ThreadComm, fopen_read, fopen_write, partition,
                        run_ranks, scan_sections)


def main():
    # SCDA_EXAMPLE_DIR pins the output location (the CI fsck smoke stage
    # runs scdatool over the files this example writes).
    tmp = os.environ.get("SCDA_EXAMPLE_DIR")
    if tmp:
        os.makedirs(tmp, exist_ok=True)
    else:
        tmp = tempfile.mkdtemp(prefix="scda-quickstart-")
    path = os.path.join(tmp, "demo.scda")

    # -- write (serial) ------------------------------------------------------
    mesh_sizes = [3, 0, 47, 12, 1, 9]          # a "hybrid mesh": ragged cells
    mesh_cells = [os.urandom(s) for s in mesh_sizes]
    with fopen_write(None, path, user_string=b"quickstart demo") as f:
        f.write_inline(b"status", b"step 000042 t 1.25e-3 ok.......\n")
        f.write_block(b"run config", b"alpha = 0.1\nbeta = 2\n")
        f.write_array(b"node coords", bytes(range(240)), [10], 24)
        f.write_varray(b"cells", mesh_cells, [6], mesh_sizes, encode=True)
    print(f"wrote {os.path.getsize(path)} bytes to {path}")

    # -- serial-equivalence: rewrite in parallel, compare bytes --------------
    path3 = os.path.join(tmp, "demo-3ranks.scda")
    counts, vcounts = [4, 2, 4], [2, 2, 2]
    offs, voffs = partition.offsets(counts), partition.offsets(vcounts)

    def rank_write(comm):
        data = bytes(range(240))
        with fopen_write(comm, path3, user_string=b"quickstart demo") as f:
            f.write_inline(b"status",
                           b"step 000042 t 1.25e-3 ok.......\n"
                           if comm.rank == 0 else None)
            f.write_block(b"run config",
                          b"alpha = 0.1\nbeta = 2\n"
                          if comm.rank == 0 else None, E=21)
            f.write_array(b"node coords",
                          data[offs[comm.rank] * 24:offs[comm.rank + 1] * 24],
                          counts, 24)
            f.write_varray(b"cells",
                           mesh_cells[voffs[comm.rank]:voffs[comm.rank + 1]],
                           vcounts,
                           mesh_sizes[voffs[comm.rank]:voffs[comm.rank + 1]],
                           encode=True)

    run_ranks(ThreadComm.group(3), rank_write)
    same = open(path, "rb").read() == open(path3, "rb").read()
    print(f"serial file == 3-rank file: {same}")
    assert same

    # -- read under a different partition -------------------------------------
    def rank_read(comm):
        with fopen_read(comm, path) as r:
            r.read_section_header(); r.skip_data()       # status
            r.read_section_header(); r.skip_data()       # config
            hdr = r.read_section_header()                # node coords
            mine = r.read_array_data([5, 5], hdr.E)      # new partition!
            hdr = r.read_section_header(decode=True)     # cells (decoded)
            sizes = r.read_varray_sizes([3, 3])
            cells = r.read_varray_data([3, 3], sizes)
            return b"".join(mine), cells

    parts = run_ranks(ThreadComm.group(2), rank_read)
    assert parts[0][0] + parts[1][0] == bytes(range(240))
    assert parts[0][1] + parts[1][1] == mesh_cells
    print("re-read under 2-rank partition: data identical")

    # -- inspect: any conforming reader can walk the file ---------------------
    print("\nsections (decode=True):")
    for h in scan_sections(path):
        print(f"  {h.type}  user={h.user_string!r:28} N={h.N:<4} E={h.E:<4} "
              f"decoded={h.decoded}")


if __name__ == "__main__":
    main()
