"""Batched serving: restore weights from an scda checkpoint, decode tokens.

Shows the serving side of the framework: a (reduced) hybrid Mamba2+attn
model (zamba2 family — O(1) SSM state + shared-attention KV cache), a
batch of concurrent requests, greedy decode with the functional cache, and
weights arriving via a partition-independent checkpoint — i.e. the serving
fleet never needs to match the training fleet's topology.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.configs import get_config, smoke
from repro.models import init_cache, init_lm, serve_step


def main():
    cfg = smoke(get_config("zamba2-2.7b"))
    key = jax.random.PRNGKey(0)

    # "training" produced a checkpoint…
    params = init_lm(cfg, key)
    ckpt = os.path.join(tempfile.mkdtemp(prefix="repro-serve-"), "w.scda")
    save(ckpt, params, step=1000)
    print(f"checkpoint: {os.path.getsize(ckpt) / 1e6:.1f} MB at {ckpt}")

    # …the serving job restores it (any topology) and serves a batch.
    weights, step = restore(ckpt, like=jax.eval_shape(
        lambda: init_lm(cfg, jax.random.PRNGKey(0))))
    print(f"restored step={step}")

    batch, max_len, prompt_len, gen_len = 4, 64, 8, 24
    cache = init_cache(cfg, batch, max_len)
    step_fn = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t))

    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    # prefill via repeated decode steps (simple; a production server would
    # run a fused prefill then switch to decode)
    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, cache = step_fn(weights, cache, prompts[:, i:i + 1])
    generated = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen_len):
        generated.append(tok)
        logits, cache = step_fn(weights, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    total_tokens = batch * (prompt_len + gen_len)
    print(f"served {batch} requests × {gen_len} new tokens "
          f"in {dt:.2f}s  ({total_tokens / dt:.1f} tok/s on CPU)")
    for b in range(batch):
        print(f"  req{b}: {list(map(int, out[b][:12]))}…")
    assert int(cache["pos"]) == prompt_len + gen_len


if __name__ == "__main__":
    main()
