"""Archival with §3 per-element compression + selective random access.

Stores a model checkpoint twice — raw and with per-chunk deflate — then
demonstrates the property the paper's per-element design buys: restoring a
*single* leaf (or a single shard of one) reads only the chunks that overlap
it, without inflating the rest of the archive.

Run:  PYTHONPATH=src python examples/compressed_archive.py
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import read_manifest, restore, save
from repro.configs import get_config, smoke
from repro.core import fopen_read
from repro.models import init_lm


def main():
    cfg = smoke(get_config("yi-6b"))
    params = init_lm(cfg, jax.random.PRNGKey(0))
    # make the weights compressible (real checkpoints often are: sparsity,
    # repeated structure, low-rank adapters, zero-init optimizer moments)
    params["embed"] = (params["embed"] * 100).round() / 100

    d = os.environ.get("SCDA_EXAMPLE_DIR")
    if d:
        os.makedirs(d, exist_ok=True)
    else:
        d = tempfile.mkdtemp(prefix="repro-archive-")
    raw, packed = os.path.join(d, "raw.scda"), os.path.join(d, "packed.scda")
    save(raw, params, step=1)
    save(packed, params, step=1, compressed=True, chunk_bytes=1 << 14)
    r, p = os.path.getsize(raw), os.path.getsize(packed)
    print(f"raw    : {r / 1e6:7.2f} MB")
    print(f"packed : {p / 1e6:7.2f} MB   (ratio {r / p:.2f}x)")

    # full restore round-trips exactly
    like = jax.eval_shape(lambda: init_lm(cfg, jax.random.PRNGKey(0)))
    out, _ = restore(packed, like)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("compressed round-trip: exact")

    # selective access: restore only the embedding leaf
    doc = read_manifest(packed)
    t0 = time.time()
    sub, _ = restore(packed, like={"embed": like["embed"]})
    dt = time.time() - t0
    np.testing.assert_array_equal(np.asarray(sub["embed"]),
                                  np.asarray(params["embed"]))
    print(f"selective restore of 'embed' "
          f"({doc['leaves'][0]['nbytes'] / 1e6:.2f} MB) in {dt * 1e3:.1f} ms "
          f"— other leaves never inflated")

    # the archive is an ordinary scda file: read one compressed element
    # directly with the core API
    with fopen_read(None, packed) as r_:
        r_.read_section_header(); r_.skip_data()          # status
        r_.read_section_header(); r_.skip_data()          # manifest
        hdr = r_.read_section_header(decode=True)         # first leaf
        first_chunk = r_.read_varray_elements([0])[0]
        print(f"leaf0 ({hdr.user_string!r}): chunk[0] = "
              f"{len(first_chunk)} bytes inflated on demand")


if __name__ == "__main__":
    main()
